//! The one causal multi-head attention — shared by the serving forward
//! ([`crate::runtime::native`]) and the training forward/backward
//! ([`crate::training::native`]), which were previously byte-duplicated
//! copies that a consistency test pinned together.
//!
//! Two formulations live behind the same entry points, selected by the
//! workspace layout:
//!
//! * **Blocked** (the original): per (sequence, head) pair the strided head
//!   columns of the packed `(rows, 3d)` qkv activation are gathered into
//!   contiguous `(t_len × hd)` Q/K/V panels held in a caller-supplied
//!   [`AttnWorkspace`], scores `S = Q·Kᵀ` come from one `matmul_nt_f32`
//!   call — a full `(t_len, t_len)` matrix per slot — the causal softmax
//!   runs row-wise in place, the weighted values `O = S·V` come from one
//!   `matmul_f32` call, and the output panel is scattered back.  Workspace
//!   memory grows as `O(slots · t²)`.
//! * **Streaming** (flash-style): K/V are tiled into `(Tc × hd)` panels and
//!   each Q row keeps a running max `m`, denominator `l`, and output
//!   accumulator (online softmax).  Per tile the `(active_rows × Tc)` score
//!   panel is computed with `matmul_nt_f32`, exponentiated against the
//!   updated running max, multiplied into the V tile with `matmul_f32`, and
//!   folded into the accumulator with the `exp(m_old − m_new)` rescale —
//!   the `(t, t)` score matrix is **never materialized**, so workspace
//!   memory grows as `O(slots · (t·hd + t·Tc))`, linear in `t`.  Causal
//!   structure additionally skips the rows above each tile's diagonal, so
//!   the streaming path does ~half the MACs of the blocked one at long `t`.
//!
//! The two callers differ in exactly one more way, so it is a parameter:
//! serving discards the softmax probs (`probs = None`), training on the
//! blocked path retains them for the backward pass (`probs = Some(buf)`).
//! The streaming backward ([`causal_attention_backward_streaming`]) instead
//! **recomputes** the probs tile by tile from qkv (one extra streaming
//! forward per pair for the `m`/`l` statistics and the `D = Σ dO⊙O` row
//! sums), so streaming training never holds a `(t, t)` buffer either.
//!
//! **Parallelism:** the `(batch × head)` panel loop fans out over the
//! persistent worker pool ([`crate::linalg::pool`]).  The workspace holds
//! `slots` independent panel sets; chunk `ci` of the pooled dispatch owns
//! slot `ci` and processes pairs `ci, ci+slots, ci+2·slots, …`, so panel
//! buffers are never shared between concurrent chunks and the whole pass
//! stays allocation-free.  Matmuls issued from inside a chunk find the pool
//! busy and run inline — the pool's deadlock-free nesting rule.

use crate::linalg::kernels;
use crate::linalg::pool::{self, SendPtr};
use crate::linalg::simd;
use crate::linalg::AlignedVec;
use crate::runtime::kvcache::PagedKvCache;

/// Default streaming K/V tile width Tc (keys gathered per panel).
pub const DEFAULT_ATTN_TILE: usize = 64;

/// Default sequence-length crossover: below this the blocked path's single
/// big `Q·Kᵀ` beats the streaming path's tile loop; at/above it the
/// `(t, t)` score matrix starts to dominate cache traffic and workspace
/// memory and the streaming path wins.
pub const DEFAULT_STREAMING_MIN_SEQ: usize = 256;

/// Which attention formulation a workspace should be laid out for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnPath {
    /// Pick by sequence length: streaming at/above `min_seq`, blocked below.
    Auto { min_seq: usize, tile: usize },
    /// Always the blocked `(t, t)`-score formulation.
    Blocked,
    /// Always the streaming formulation at the given K/V tile width.
    Streaming { tile: usize },
}

impl AttnPath {
    /// The built-in crossover/tile defaults.
    pub fn auto_default() -> AttnPath {
        AttnPath::Auto { min_seq: DEFAULT_STREAMING_MIN_SEQ, tile: DEFAULT_ATTN_TILE }
    }

    /// Resolve to a concrete layout for sequences up to `seq` tokens:
    /// `Some(tile)` = streaming, `None` = blocked.
    pub fn resolve(self, seq: usize) -> Option<usize> {
        match self {
            AttnPath::Auto { min_seq, tile } => (seq >= min_seq).then_some(tile),
            AttnPath::Blocked => None,
            AttnPath::Streaming { tile } => Some(tile),
        }
    }
}

/// Preallocated panel workspace for the shared attention: `slots`
/// independent panel sets laid out for one [`AttnPath`].  Sized once;
/// [`causal_attention`] never allocates.
///
/// Blocked layout per slot: Q/K/V/O `(seq × hd)` panels + one `(seq × seq)`
/// score matrix.  Streaming layout per slot: Q/O-accumulator/O-tile
/// `(seq × hd)` panels, K/V `(tile × hd)` tiles, one `(seq × tile)` score
/// tile, and `3·seq` running stats (max, denominator, rescale) — no buffer
/// is quadratic in `seq` as long as `tile < seq` (see
/// [`AttnWorkspace::new_streaming`] for the degenerate case).
#[derive(Debug)]
pub struct AttnWorkspace {
    seq: usize,
    hd: usize,
    slots: usize,
    /// `Some(tc)` = streaming layout at tile width `tc`; `None` = blocked.
    tile: Option<usize>,
    q: AlignedVec<f32>,
    k: AlignedVec<f32>,
    v: AlignedVec<f32>,
    o: AlignedVec<f32>,
    scores: AlignedVec<f32>,
    otile: AlignedVec<f32>,
    stats: AlignedVec<f32>,
}

impl AttnWorkspace {
    /// Blocked workspace for sequences up to `seq` tokens at head width
    /// `hd`, with `slots` concurrent panel sets (1 = sequential head loop).
    pub fn new(seq: usize, hd: usize, slots: usize) -> AttnWorkspace {
        let slots = slots.max(1);
        AttnWorkspace {
            seq,
            hd,
            slots,
            tile: None,
            q: AlignedVec::zeroed(slots * seq * hd),
            k: AlignedVec::zeroed(slots * seq * hd),
            v: AlignedVec::zeroed(slots * seq * hd),
            o: AlignedVec::zeroed(slots * seq * hd),
            scores: AlignedVec::zeroed(slots * seq * seq),
            otile: AlignedVec::new(),
            stats: AlignedVec::new(),
        }
    }

    /// Streaming workspace at K/V tile width `tile` (clamped to
    /// `[1, seq]`).  The sub-quadratic memory contract assumes `tile < seq`
    /// — the intended regime, and what the crossover defaults guarantee
    /// (tile 64 ≪ min_seq 256).  A tile at/above `seq` degenerates to a
    /// single panel whose `(seq × tile)` score buffer is the blocked
    /// footprint again: still numerically correct (the equivalence suite
    /// exercises it), but no memory win.
    pub fn new_streaming(seq: usize, hd: usize, slots: usize, tile: usize) -> AttnWorkspace {
        let slots = slots.max(1);
        let tile = tile.clamp(1, seq.max(1));
        AttnWorkspace {
            seq,
            hd,
            slots,
            tile: Some(tile),
            q: AlignedVec::zeroed(slots * seq * hd),
            k: AlignedVec::zeroed(slots * tile * hd),
            v: AlignedVec::zeroed(slots * tile * hd),
            o: AlignedVec::zeroed(slots * seq * hd),
            scores: AlignedVec::zeroed(slots * seq * tile),
            otile: AlignedVec::zeroed(slots * seq * hd),
            stats: AlignedVec::zeroed(slots * 3 * seq),
        }
    }

    /// Workspace laid out per `path.resolve(seq)`.
    pub fn with_path(seq: usize, hd: usize, slots: usize, path: AttnPath) -> AttnWorkspace {
        match path.resolve(seq) {
            Some(tile) => AttnWorkspace::new_streaming(seq, hd, slots, tile),
            None => AttnWorkspace::new(seq, hd, slots),
        }
    }

    /// Slot count that saturates the worker pool for a panel loop over
    /// `max_pairs = batch × heads` (batch, head) pairs: more slots than
    /// pool threads only waste memory, more than pairs never run.
    pub fn auto_slots(max_pairs: usize) -> usize {
        pool::saturating_slots(max_pairs)
    }

    /// `Some(tile)` when laid out for the streaming path.
    pub fn tile(&self) -> Option<usize> {
        self.tile
    }

    /// Whether this workspace drives the streaming (flash-style) path.
    pub fn is_streaming(&self) -> bool {
        self.tile.is_some()
    }

    /// Human-readable path tag for bench/log lines.
    pub fn path_label(&self) -> String {
        match self.tile {
            Some(tc) => format!("streaming(tile={tc})"),
            None => "blocked".to_string(),
        }
    }

    /// Total f32 elements across every buffer — the workspace memory
    /// footprint tests do size accounting against.
    pub fn total_floats(&self) -> usize {
        self.q.len()
            + self.k.len()
            + self.v.len()
            + self.o.len()
            + self.scores.len()
            + self.otile.len()
            + self.stats.len()
    }

    /// Largest single per-slot panel in f32 elements: `seq²` for the
    /// blocked layout, `max(seq·hd, seq·tile)` for streaming — the quantity
    /// the no-`(t, t)`-buffer contract bounds.
    pub fn max_slot_panel_floats(&self) -> usize {
        [
            self.q.len(),
            self.k.len(),
            self.v.len(),
            self.o.len(),
            self.scores.len(),
            self.otile.len(),
            self.stats.len(),
        ]
        .into_iter()
        .map(|len| len / self.slots)
        .max()
        .unwrap_or(0)
    }

    /// Buffer base pointers — lets tests assert repeated attention calls
    /// never reallocate (the zero-per-request-allocation invariant).
    pub fn fingerprint(&self) -> Vec<usize> {
        vec![
            self.q.as_ptr() as usize,
            self.k.as_ptr() as usize,
            self.v.as_ptr() as usize,
            self.o.as_ptr() as usize,
            self.scores.as_ptr() as usize,
            self.otile.as_ptr() as usize,
            self.stats.as_ptr() as usize,
        ]
    }
}

/// Backward-pass panel workspace.  Blocked layout per slot: seven
/// `(seq × hd)` panels (Q/K/V gathers, dO, dQ, dK, dV) plus one
/// `(seq × seq)` dS matrix.  Streaming layout per slot: five `(seq × hd)`
/// panels (Q, dO, dQ, recomputed O, tile staging), four `(tile × hd)` K/V
/// tiles (K, V, dK, dV), two `(seq × tile)` score tiles (P, dP), and
/// `4·seq` stats (m, l, rescale, `D = Σ dO⊙O`) — nothing quadratic in
/// `seq`.
#[derive(Debug)]
pub struct AttnGradWorkspace {
    seq: usize,
    hd: usize,
    slots: usize,
    /// `Some(tc)` = streaming recompute layout; `None` = retained-probs.
    tile: Option<usize>,
    panels: AlignedVec<f32>,
}

/// Per-slot f32 stride of the streaming grad layout.
fn stream_grad_stride(seq: usize, hd: usize, tile: usize) -> usize {
    5 * seq * hd + 4 * tile * hd + 2 * seq * tile + 4 * seq
}

impl AttnGradWorkspace {
    /// Retained-probs (blocked) backward workspace.
    pub fn new(seq: usize, hd: usize, slots: usize) -> AttnGradWorkspace {
        let slots = slots.max(1);
        AttnGradWorkspace {
            seq,
            hd,
            slots,
            tile: None,
            panels: AlignedVec::zeroed(slots * (7 * seq * hd + seq * seq)),
        }
    }

    /// Recompute-based (streaming) backward workspace at tile width `tile`.
    pub fn new_streaming(seq: usize, hd: usize, slots: usize, tile: usize) -> AttnGradWorkspace {
        let slots = slots.max(1);
        let tile = tile.clamp(1, seq.max(1));
        AttnGradWorkspace {
            seq,
            hd,
            slots,
            tile: Some(tile),
            panels: AlignedVec::zeroed(slots * stream_grad_stride(seq, hd, tile)),
        }
    }

    /// `Some(tile)` when laid out for the streaming recompute backward.
    pub fn tile(&self) -> Option<usize> {
        self.tile
    }

    /// Total f32 elements (size-accounting tests).
    pub fn total_floats(&self) -> usize {
        self.panels.len()
    }

    pub fn fingerprint(&self) -> Vec<usize> {
        vec![self.panels.as_ptr() as usize]
    }
}

/// Scale + causal softmax over the first `t_len` rows of `sc` in place:
/// row `t` normalizes entries `0..=t` and zeroes the strict upper triangle
/// (masked keys must contribute exactly nothing to `S·V`).  The row-wide
/// scale/max, exp/sum, and normalize passes run on the dispatched SIMD
/// micro-kernels (see [`simd`]).
fn masked_softmax_rows(sc: &mut [f32], t_len: usize, scale: f32) {
    for t1 in 0..t_len {
        let srow = &mut sc[t1 * t_len..t1 * t_len + t1 + 1];
        let mx = simd::scale_max(srow, scale);
        let sum = simd::exp_sub_sum(srow, mx);
        simd::scale_in_place(srow, 1.0 / sum);
        for s in sc[t1 * t_len + t1 + 1..(t1 + 1) * t_len].iter_mut() {
            *s = 0.0;
        }
    }
}

/// Gather one head's strided Q/K/V columns for rows `base..base + t_len`
/// of the packed `(rows, 3d)` qkv buffer into contiguous panels.
#[allow(clippy::too_many_arguments)]
fn gather_rows(
    qkv: &[f32],
    base: usize,
    w3: usize,
    off: usize,
    hd: usize,
    rows: std::ops::Range<usize>,
    dst: &mut [f32],
) {
    for (i, t) in rows.enumerate() {
        let row = (base + t) * w3 + off;
        dst[i * hd..(i + 1) * hd].copy_from_slice(&qkv[row..row + hd]);
    }
}

/// One (batch, head) pair of the streaming forward over a slot's panels.
/// Leaves the **unnormalized** output accumulator in `oh` and the final
/// running max / denominator in `m` / `l` (callers divide by `l` — the
/// forward scatters `oh/l`, the backward also needs `m`/`l` to recompute
/// probs).  `ch` is per-row rescale staging.
#[allow(clippy::too_many_arguments)]
fn stream_pair_forward(
    qkv: &[f32],
    base: usize,
    w3: usize,
    ko: usize,
    vo: usize,
    t_len: usize,
    hd: usize,
    scale: f32,
    tc: usize,
    qh: &[f32],
    kt: &mut [f32],
    vt: &mut [f32],
    oh: &mut [f32],
    ot: &mut [f32],
    pt: &mut [f32],
    m: &mut [f32],
    l: &mut [f32],
    ch: &mut [f32],
) {
    let mut j0 = 0usize;
    while j0 < t_len {
        let jlen = tc.min(t_len - j0);
        gather_rows(qkv, base, w3, ko, hd, j0..j0 + jlen, kt);
        gather_rows(qkv, base, w3, vo, hd, j0..j0 + jlen, vt);
        // Causal: rows above the tile's diagonal see none of its keys —
        // only rows `j0..t_len` participate.
        let ra = t_len - j0;
        let p = &mut pt[..ra * jlen];
        kernels::matmul_nt_f32(&qh[j0 * hd..t_len * hd], &kt[..jlen * hd], ra, hd, jlen, p);
        let first = j0 == 0;
        for i in 0..ra {
            let t1 = j0 + i;
            // Row t1 sees keys t2 ≤ t1 → local indices < t1 − j0 + 1.
            let vis = jlen.min(i + 1);
            let prow = &mut p[i * jlen..(i + 1) * jlen];
            let tm = simd::scale_max(&mut prow[..vis], scale);
            // Per-row running stats stay scalar: `corr` mixes state across
            // tiles and must keep the legacy exp on the −∞ first-tile edge.
            let m_new = if first { tm } else { m[t1].max(tm) };
            let corr = if first { 0.0 } else { (m[t1] - m_new).exp() };
            let tsum = simd::exp_sub_sum(&mut prow[..vis], m_new);
            for s in prow[vis..].iter_mut() {
                *s = 0.0;
            }
            l[t1] = if first { tsum } else { l[t1] * corr + tsum };
            m[t1] = m_new;
            ch[t1] = corr;
        }
        if first {
            // Tile 0 covers every row: write the accumulator directly, no
            // stale state from a previous pair survives.
            kernels::matmul_f32(p, &vt[..jlen * hd], ra, jlen, hd, &mut oh[..ra * hd]);
        } else {
            kernels::matmul_f32(p, &vt[..jlen * hd], ra, jlen, hd, &mut ot[..ra * hd]);
            for i in 0..ra {
                let t1 = j0 + i;
                simd::rescale_add(
                    &mut oh[t1 * hd..(t1 + 1) * hd],
                    &ot[i * hd..(i + 1) * hd],
                    ch[t1],
                );
            }
        }
        j0 += jlen;
    }
}

/// Causal multi-head attention over the packed qkv buffer (`(batch·t_len,
/// 3d)`: q | k | v, heads interleaved within each third), merged heads
/// written to `att` (`(batch·t_len, d)`).  The workspace layout selects the
/// formulation: blocked ([`AttnWorkspace::new`]) or streaming
/// ([`AttnWorkspace::new_streaming`]) — both compute the same function to
/// f32 rounding (the equivalence suite pins them against a scalar oracle).
///
/// `probs = Some(buf)` retains the causal softmax weights — `buf` must hold
/// `batch · heads · t_len²` floats, one `(t_len, t_len)` matrix per
/// (batch, head) pair — for the retained-probs backward pass
/// ([`causal_attention_backward`]); it requires a **blocked** workspace
/// (the streaming path exists precisely to never build those matrices; its
/// backward recomputes them tile by tile instead).  `None` discards.
///
/// Allocation-free: all intermediates live in `ws`; the `(batch × head)`
/// pair loop fans out over the worker pool, one workspace slot per chunk.
#[allow(clippy::too_many_arguments)]
pub fn causal_attention(
    qkv: &[f32],
    batch: usize,
    t_len: usize,
    d: usize,
    heads: usize,
    ws: &mut AttnWorkspace,
    att: &mut [f32],
    probs: Option<&mut [f32]>,
) {
    assert!(heads > 0 && d % heads == 0, "d {d} not divisible by heads {heads}");
    let hd = d / heads;
    assert_eq!(hd, ws.hd, "workspace head width mismatch");
    assert!(t_len <= ws.seq, "workspace sized for seq {}, got {t_len}", ws.seq);
    assert!(
        probs.is_none() || ws.tile.is_none(),
        "probs retention requires a blocked workspace (streaming never materializes (t, t))"
    );
    let rows = batch * t_len;
    let w3 = 3 * d;
    assert!(qkv.len() >= rows * w3, "qkv buffer too small");
    assert!(att.len() >= rows * d, "att buffer too small");
    let n_pairs = batch * heads;
    if n_pairs == 0 || t_len == 0 {
        return;
    }
    let scale = 1.0 / (hd as f32).sqrt();
    let slots = ws.slots.min(n_pairs);

    let probs_ptr = probs.map(|p| {
        assert_eq!(p.len(), n_pairs * t_len * t_len, "probs buffer size");
        SendPtr(p.as_mut_ptr())
    });
    let att_ptr = SendPtr(att.as_mut_ptr());
    let (qp, kp, vp, op, sp) = (
        SendPtr(ws.q.as_mut_ptr()),
        SendPtr(ws.k.as_mut_ptr()),
        SendPtr(ws.v.as_mut_ptr()),
        SendPtr(ws.o.as_mut_ptr()),
        SendPtr(ws.scores.as_mut_ptr()),
    );
    let (otp, stp) = (SendPtr(ws.otile.as_mut_ptr()), SendPtr(ws.stats.as_mut_ptr()));
    let panel = ws.seq * ws.hd;
    let ws_seq = ws.seq;

    match ws.tile {
        None => {
            let smat = ws_seq * ws_seq;
            pool::parallel_for(slots, &|ci| {
                // SAFETY: slot regions `[ci·panel, ci·panel + t_len·hd)` are
                // disjoint across chunk indices (ci < slots), and `ws` is
                // borrowed mutably for the whole dispatch, so nothing else
                // touches them.
                let (qh, kh, vh, oh, slot_sc) = unsafe {
                    (
                        std::slice::from_raw_parts_mut(qp.0.add(ci * panel), t_len * hd),
                        std::slice::from_raw_parts_mut(kp.0.add(ci * panel), t_len * hd),
                        std::slice::from_raw_parts_mut(vp.0.add(ci * panel), t_len * hd),
                        std::slice::from_raw_parts_mut(op.0.add(ci * panel), t_len * hd),
                        std::slice::from_raw_parts_mut(sp.0.add(ci * smat), t_len * t_len),
                    )
                };
                for pair in (ci..n_pairs).step_by(slots) {
                    let b = pair / heads;
                    let head = pair % heads;
                    let base = b * t_len;
                    let (qo, ko, vo) = (head * hd, d + head * hd, 2 * d + head * hd);
                    gather_rows(qkv, base, w3, qo, hd, 0..t_len, qh);
                    gather_rows(qkv, base, w3, ko, hd, 0..t_len, kh);
                    gather_rows(qkv, base, w3, vo, hd, 0..t_len, vh);
                    // Scores land directly in the retained probs matrix when
                    // the caller keeps them, in the slot scratch otherwise.
                    // SAFETY: (Some arm) pair regions `[pair·t_len², (pair+1)·t_len²)`
                    // are disjoint across pairs, and each pair is processed
                    // exactly once (strided partition over ci).
                    let sc: &mut [f32] = match probs_ptr {
                        Some(p) => unsafe {
                            std::slice::from_raw_parts_mut(
                                p.0.add(pair * t_len * t_len),
                                t_len * t_len,
                            )
                        },
                        None => &mut slot_sc[..],
                    };
                    kernels::matmul_nt_f32(qh, kh, t_len, hd, t_len, sc);
                    masked_softmax_rows(sc, t_len, scale);
                    kernels::matmul_f32(sc, vh, t_len, t_len, hd, oh);
                    for t1 in 0..t_len {
                        let dst = (base + t1) * d + head * hd;
                        // SAFETY: pair (b, head) owns columns
                        // [head·hd, (head+1)·hd) of rows [base, base + t_len)
                        // — disjoint across pairs.
                        let out =
                            unsafe { std::slice::from_raw_parts_mut(att_ptr.0.add(dst), hd) };
                        out.copy_from_slice(&oh[t1 * hd..(t1 + 1) * hd]);
                    }
                }
            });
        }
        Some(tc) => {
            let kpanel = tc * ws.hd;
            let ptile = ws_seq * tc;
            pool::parallel_for(slots, &|ci| {
                // SAFETY: same per-slot disjointness as the blocked arm,
                // with the streaming strides (kpanel, ptile, 3·seq stats).
                let (qh, kt, vt, oh, ot, pt, st) = unsafe {
                    (
                        std::slice::from_raw_parts_mut(qp.0.add(ci * panel), t_len * hd),
                        std::slice::from_raw_parts_mut(kp.0.add(ci * kpanel), kpanel),
                        std::slice::from_raw_parts_mut(vp.0.add(ci * kpanel), kpanel),
                        std::slice::from_raw_parts_mut(op.0.add(ci * panel), t_len * hd),
                        std::slice::from_raw_parts_mut(otp.0.add(ci * panel), t_len * hd),
                        std::slice::from_raw_parts_mut(sp.0.add(ci * ptile), t_len * tc),
                        std::slice::from_raw_parts_mut(stp.0.add(ci * 3 * ws_seq), 3 * t_len),
                    )
                };
                let (m, rest) = st.split_at_mut(t_len);
                let (l, ch) = rest.split_at_mut(t_len);
                for pair in (ci..n_pairs).step_by(slots) {
                    let b = pair / heads;
                    let head = pair % heads;
                    let base = b * t_len;
                    let (qo, ko, vo) = (head * hd, d + head * hd, 2 * d + head * hd);
                    gather_rows(qkv, base, w3, qo, hd, 0..t_len, qh);
                    stream_pair_forward(
                        qkv, base, w3, ko, vo, t_len, hd, scale, tc, qh, kt, vt, oh, ot, pt, m,
                        l, ch,
                    );
                    for t1 in 0..t_len {
                        let inv = 1.0 / l[t1];
                        let dst = (base + t1) * d + head * hd;
                        // SAFETY: pair (b, head) owns columns
                        // [head·hd, (head+1)·hd) of rows [base, base + t_len)
                        // — disjoint across pairs.
                        let out =
                            unsafe { std::slice::from_raw_parts_mut(att_ptr.0.add(dst), hd) };
                        for (o, &x) in out.iter_mut().zip(&oh[t1 * hd..(t1 + 1) * hd]) {
                            *o = x * inv;
                        }
                    }
                }
            });
        }
    }
}

/// Preallocated staging for the paged single-query decode attention:
/// `slots` independent (score-tile, output-accumulator) pairs, one per
/// pooled chunk of the (row × head) decode loop.  Per slot: `page_size`
/// score floats (one page of keys at a time — the decode analogue of the
/// streaming score tile) and `hd` accumulator floats.  Sized once;
/// [`paged_decode_attention`] never allocates.
#[derive(Debug)]
pub struct DecodeWorkspace {
    hd: usize,
    page_size: usize,
    slots: usize,
    scores: AlignedVec<f32>,
    acc: AlignedVec<f32>,
}

impl DecodeWorkspace {
    pub fn new(hd: usize, page_size: usize, slots: usize) -> DecodeWorkspace {
        let slots = slots.max(1);
        DecodeWorkspace {
            hd,
            page_size,
            slots,
            scores: AlignedVec::zeroed(slots * page_size),
            acc: AlignedVec::zeroed(slots * hd),
        }
    }

    /// Independent staging slots (the pooled fan-out width).
    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Buffer base pointers for the zero-allocation pin.
    pub fn fingerprint(&self) -> Vec<usize> {
        vec![self.scores.as_ptr() as usize, self.acc.as_ptr() as usize]
    }
}

/// Single-query causal attention for one (request-slot, layer, head) stream:
/// `out = softmax(q·Kᵀ·scale)·V` over the first `kv_len` cached positions,
/// consuming the K/V pages as natural `(page_size × hd)` tiles with the
/// same online-softmax recurrence as [`stream_pair_forward`] — per tile a
/// running max `m` and denominator `l` merge via `corr = exp(m_old −
/// m_new)` (with the legacy `corr = 0` convention on the first tile), and
/// the accumulator is rescaled before the tile's weighted values fold in.
///
/// `scores` must hold `page_size` floats, `acc` and `out` must hold `hd`
/// (`= q.len()`) floats each.  Causality is positional: the caller passes
/// `kv_len` = the query's position + 1, and the cache holds exactly the
/// rows before it, so no mask is needed.
#[allow(clippy::too_many_arguments)]
pub fn decode_attend_paged(
    cache: &PagedKvCache,
    slot: usize,
    layer: usize,
    head: usize,
    q: &[f32],
    kv_len: usize,
    scale: f32,
    scores: &mut [f32],
    acc: &mut [f32],
    out: &mut [f32],
) {
    let hd = q.len();
    let ps = cache.page_size();
    debug_assert!(kv_len > 0, "a decode query always sees at least itself");
    debug_assert!(scores.len() >= ps && acc.len() >= hd && out.len() >= hd);
    let acc = &mut acc[..hd];
    let mut m = 0f32;
    let mut l = 0f32;
    let mut pos = 0usize;
    let mut page = 0usize;
    while pos < kv_len {
        let jlen = ps.min(kv_len - pos);
        let kt = cache.k_page(slot, layer, head, page);
        let vt = cache.v_page(slot, layer, head, page);
        let sc = &mut scores[..jlen];
        for (j, s) in sc.iter_mut().enumerate() {
            *s = simd::dot_f32(q, &kt[j * hd..(j + 1) * hd]);
        }
        let first = pos == 0;
        let tm = simd::scale_max(sc, scale);
        // The running stats mix state across tiles and keep the legacy exp
        // on the −∞ first-tile edge, exactly like `stream_pair_forward`.
        let m_new = if first { tm } else { m.max(tm) };
        let corr = if first { 0.0 } else { (m - m_new).exp() };
        let tsum = simd::exp_sub_sum(sc, m_new);
        l = if first { tsum } else { l * corr + tsum };
        m = m_new;
        if first {
            acc.fill(0.0);
        } else {
            simd::scale_in_place(acc, corr);
        }
        // acc += scᵀ · V_tile: the tile's rows enter in page order, so the
        // summation order is a pure function of (kv_len, page_size) — a row
        // decodes bit-identically whatever batch it shares a step with.
        simd::axpy4_f32(sc, &vt[..jlen * hd], hd, acc);
        pos += jlen;
        page += 1;
    }
    let inv = 1.0 / l;
    for (o, &a) in out[..hd].iter_mut().zip(acc.iter()) {
        *o = a * inv;
    }
}

/// Paged attention for a batch of incremental rows (prefill rows and
/// single-token decode rows look identical here): row `r` of the packed
/// `(rows, 3d)` qkv buffer queries the K/V stream of request slot
/// `row_slots[r]` over its first `row_lens[r]` cached positions, merged
/// heads landing in `att` (`(rows, d)`).  The (row × head) pair loop fans
/// out over the worker pool slot-strided, one [`DecodeWorkspace`] staging
/// slot per chunk — the same disjoint-slice discipline as
/// [`causal_attention`], and just as allocation-free.
#[allow(clippy::too_many_arguments)]
pub fn paged_decode_attention(
    cache: &PagedKvCache,
    qkv: &[f32],
    row_slots: &[usize],
    row_lens: &[usize],
    layer: usize,
    d: usize,
    heads: usize,
    ws: &mut DecodeWorkspace,
    att: &mut [f32],
) {
    assert!(heads > 0 && d % heads == 0, "d {d} not divisible by heads {heads}");
    let hd = d / heads;
    assert_eq!(hd, ws.hd, "decode workspace head width mismatch");
    assert_eq!(cache.page_size(), ws.page_size, "decode workspace page size mismatch");
    let rows = row_slots.len();
    assert_eq!(rows, row_lens.len());
    let w3 = 3 * d;
    assert!(qkv.len() >= rows * w3, "qkv buffer too small");
    assert!(att.len() >= rows * d, "att buffer too small");
    let n_pairs = rows * heads;
    if n_pairs == 0 {
        return;
    }
    let scale = 1.0 / (hd as f32).sqrt();
    let slots = ws.slots.min(n_pairs);
    let ps = ws.page_size;
    let att_ptr = SendPtr(att.as_mut_ptr());
    let scp = SendPtr(ws.scores.as_mut_ptr());
    let accp = SendPtr(ws.acc.as_mut_ptr());
    pool::parallel_for(slots, &|ci| {
        // SAFETY: staging regions `[ci·ps, (ci+1)·ps)` / `[ci·hd, (ci+1)·hd)`
        // are disjoint across chunk indices (ci < slots ≤ ws.slots), and
        // `ws` is borrowed mutably for the whole dispatch.
        let (sc, acc) = unsafe {
            (
                std::slice::from_raw_parts_mut(scp.0.add(ci * ps), ps),
                std::slice::from_raw_parts_mut(accp.0.add(ci * hd), hd),
            )
        };
        for pair in (ci..n_pairs).step_by(slots) {
            let r = pair / heads;
            let head = pair % heads;
            let q = &qkv[r * w3 + head * hd..r * w3 + head * hd + hd];
            // SAFETY: pair (r, head) owns columns [head·hd, (head+1)·hd) of
            // att row r — disjoint across pairs, each processed once.
            let out = unsafe {
                std::slice::from_raw_parts_mut(att_ptr.0.add(r * d + head * hd), hd)
            };
            decode_attend_paged(
                cache,
                row_slots[r],
                layer,
                head,
                q,
                row_lens[r],
                scale,
                sc,
                acc,
                out,
            );
        }
    });
}

/// Backward through the causal attention: `datt` (rows, d) and the retained
/// `probs` from [`causal_attention`] → `dqkv` (rows, 3d).  Same slot-strided
/// pooled pair loop as the forward; allocation-free given a **blocked**
/// `ws` ([`AttnGradWorkspace::new`]).  The streaming counterpart that needs
/// no retained probs is [`causal_attention_backward_streaming`].
#[allow(clippy::too_many_arguments)]
pub fn causal_attention_backward(
    qkv: &[f32],
    probs: &[f32],
    datt: &[f32],
    batch: usize,
    t_len: usize,
    d: usize,
    heads: usize,
    ws: &mut AttnGradWorkspace,
    dqkv: &mut [f32],
) {
    assert!(heads > 0 && d % heads == 0, "d {d} not divisible by heads {heads}");
    let hd = d / heads;
    assert_eq!(hd, ws.hd, "grad workspace head width mismatch");
    assert!(t_len <= ws.seq, "grad workspace sized for seq {}, got {t_len}", ws.seq);
    assert!(ws.tile.is_none(), "retained-probs backward requires a blocked grad workspace");
    let rows = batch * t_len;
    let w3 = 3 * d;
    let n_pairs = batch * heads;
    assert!(qkv.len() >= rows * w3 && datt.len() >= rows * d && dqkv.len() >= rows * w3);
    assert!(probs.len() >= n_pairs * t_len * t_len, "probs buffer too small");
    if n_pairs == 0 || t_len == 0 {
        return;
    }
    let scale = 1.0 / (hd as f32).sqrt();
    let slots = ws.slots.min(n_pairs);

    let dqkv_ptr = SendPtr(dqkv.as_mut_ptr());
    let panels_ptr = SendPtr(ws.panels.as_mut_ptr());
    let panel = ws.seq * ws.hd;
    let slot_stride = 7 * panel + ws.seq * ws.seq;

    pool::parallel_for(slots, &|ci| {
        // SAFETY: slot `ci` owns panels `[ci·slot_stride, (ci+1)·slot_stride)`
        // — disjoint across chunk indices; `ws` is mutably borrowed for the
        // whole dispatch.
        let slot = unsafe {
            std::slice::from_raw_parts_mut(panels_ptr.0.add(ci * slot_stride), slot_stride)
        };
        let (qh, rest) = slot.split_at_mut(panel);
        let (kh, rest) = rest.split_at_mut(panel);
        let (vh, rest) = rest.split_at_mut(panel);
        let (doh, rest) = rest.split_at_mut(panel);
        let (dqh, rest) = rest.split_at_mut(panel);
        let (dkh, rest) = rest.split_at_mut(panel);
        let (dvh, ds) = rest.split_at_mut(panel);
        let (qh, kh, vh) = (&mut qh[..t_len * hd], &mut kh[..t_len * hd], &mut vh[..t_len * hd]);
        let (doh, dqh) = (&mut doh[..t_len * hd], &mut dqh[..t_len * hd]);
        let (dkh, dvh) = (&mut dkh[..t_len * hd], &mut dvh[..t_len * hd]);
        let ds = &mut ds[..t_len * t_len];
        for pair in (ci..n_pairs).step_by(slots) {
            let b = pair / heads;
            let head = pair % heads;
            let base = b * t_len;
            let (qo, ko, vo) = (head * hd, d + head * hd, 2 * d + head * hd);
            gather_rows(qkv, base, w3, qo, hd, 0..t_len, qh);
            gather_rows(qkv, base, w3, ko, hd, 0..t_len, kh);
            gather_rows(qkv, base, w3, vo, hd, 0..t_len, vh);
            gather_rows(datt, base, d, head * hd, hd, 0..t_len, doh);
            let p = &probs[pair * t_len * t_len..(pair + 1) * t_len * t_len];
            // dV = Pᵀ·dO
            for x in dvh.iter_mut() {
                *x = 0.0;
            }
            kernels::matmul_tn_acc_f32(p, doh, t_len, t_len, hd, dvh);
            // dP = dO·Vᵀ
            kernels::matmul_nt_f32(doh, vh, t_len, hd, t_len, ds);
            // dS = P ⊙ (dP − Σ_j dP⊙P) · scale  (strict upper triangle stays 0)
            for t1 in 0..t_len {
                let prow = &p[t1 * t_len..(t1 + 1) * t_len];
                let dsrow = &mut ds[t1 * t_len..(t1 + 1) * t_len];
                let mut dot = 0f32;
                for j in 0..=t1 {
                    dot += dsrow[j] * prow[j];
                }
                for j in 0..t_len {
                    dsrow[j] = if j <= t1 { prow[j] * (dsrow[j] - dot) * scale } else { 0.0 };
                }
            }
            // dQ = dS·K ; dK = dSᵀ·Q
            kernels::matmul_f32(ds, kh, t_len, t_len, hd, dqh);
            for x in dkh.iter_mut() {
                *x = 0.0;
            }
            kernels::matmul_tn_acc_f32(ds, qh, t_len, t_len, hd, dkh);
            for t1 in 0..t_len {
                let row = (base + t1) * w3;
                // SAFETY: pair (b, head) owns the q/k/v column ranges of its
                // head within rows [base, base + t_len) — disjoint across
                // pairs (every pair is processed exactly once).
                let (dq, dk, dv) = unsafe {
                    (
                        std::slice::from_raw_parts_mut(dqkv_ptr.0.add(row + qo), hd),
                        std::slice::from_raw_parts_mut(dqkv_ptr.0.add(row + ko), hd),
                        std::slice::from_raw_parts_mut(dqkv_ptr.0.add(row + vo), hd),
                    )
                };
                dq.copy_from_slice(&dqh[t1 * hd..(t1 + 1) * hd]);
                dk.copy_from_slice(&dkh[t1 * hd..(t1 + 1) * hd]);
                dv.copy_from_slice(&dvh[t1 * hd..(t1 + 1) * hd]);
            }
        }
    });
}

/// Recompute-based (flash-style) backward: `datt` (rows, d) → `dqkv`
/// (rows, 3d) with **no retained probs** — per (batch, head) pair the
/// streaming forward is replayed once to rebuild the per-row softmax
/// statistics (`m`, `l`) and the unnormalized output (for `D = Σ dO⊙O`),
/// then each K/V tile's probability panel is recomputed as
/// `exp(scale·S − m)/l` and consumed immediately by the dV/dP/dS/dQ/dK
/// products.  Nothing quadratic in `t_len` is ever held; allocation-free
/// given a streaming `ws` ([`AttnGradWorkspace::new_streaming`]).
///
/// Same slot-strided pooled pair loop as the forward.  Matches
/// [`causal_attention_backward`] to f32 rounding (the equivalence suite
/// pins the two against each other and against finite differences).
#[allow(clippy::too_many_arguments)]
pub fn causal_attention_backward_streaming(
    qkv: &[f32],
    datt: &[f32],
    batch: usize,
    t_len: usize,
    d: usize,
    heads: usize,
    ws: &mut AttnGradWorkspace,
    dqkv: &mut [f32],
) {
    assert!(heads > 0 && d % heads == 0, "d {d} not divisible by heads {heads}");
    let hd = d / heads;
    assert_eq!(hd, ws.hd, "grad workspace head width mismatch");
    assert!(t_len <= ws.seq, "grad workspace sized for seq {}, got {t_len}", ws.seq);
    let tc = ws.tile.expect("streaming backward requires a streaming grad workspace");
    let rows = batch * t_len;
    let w3 = 3 * d;
    let n_pairs = batch * heads;
    assert!(qkv.len() >= rows * w3 && datt.len() >= rows * d && dqkv.len() >= rows * w3);
    if n_pairs == 0 || t_len == 0 {
        return;
    }
    let scale = 1.0 / (hd as f32).sqrt();
    let slots = ws.slots.min(n_pairs);

    let dqkv_ptr = SendPtr(dqkv.as_mut_ptr());
    let panels_ptr = SendPtr(ws.panels.as_mut_ptr());
    let panel = ws.seq * ws.hd;
    let kpanel = tc * ws.hd;
    let ptile = ws.seq * tc;
    let slot_stride = stream_grad_stride(ws.seq, ws.hd, tc);
    let ws_seq = ws.seq;

    pool::parallel_for(slots, &|ci| {
        // SAFETY: slot `ci` owns panels `[ci·slot_stride, (ci+1)·slot_stride)`
        // — disjoint across chunk indices; `ws` is mutably borrowed for the
        // whole dispatch.
        let slot = unsafe {
            std::slice::from_raw_parts_mut(panels_ptr.0.add(ci * slot_stride), slot_stride)
        };
        let (qh, rest) = slot.split_at_mut(panel);
        let (doh, rest) = rest.split_at_mut(panel);
        let (dqh, rest) = rest.split_at_mut(panel);
        let (oh, rest) = rest.split_at_mut(panel);
        let (tmp, rest) = rest.split_at_mut(panel);
        let (kt, rest) = rest.split_at_mut(kpanel);
        let (vt, rest) = rest.split_at_mut(kpanel);
        let (dkt, rest) = rest.split_at_mut(kpanel);
        let (dvt, rest) = rest.split_at_mut(kpanel);
        let (pt, rest) = rest.split_at_mut(ptile);
        let (dpt, stats) = rest.split_at_mut(ptile);
        let (qh, doh) = (&mut qh[..t_len * hd], &mut doh[..t_len * hd]);
        let (dqh, oh) = (&mut dqh[..t_len * hd], &mut oh[..t_len * hd]);
        let tmp = &mut tmp[..t_len * hd];
        let (m, rest) = stats.split_at_mut(ws_seq);
        let (l, rest) = rest.split_at_mut(ws_seq);
        let (ch, dsum) = rest.split_at_mut(ws_seq);
        let (m, l, ch) = (&mut m[..t_len], &mut l[..t_len], &mut ch[..t_len]);
        let dsum = &mut dsum[..t_len];
        for pair in (ci..n_pairs).step_by(slots) {
            let b = pair / heads;
            let head = pair % heads;
            let base = b * t_len;
            let (qo, ko, vo) = (head * hd, d + head * hd, 2 * d + head * hd);
            gather_rows(qkv, base, w3, qo, hd, 0..t_len, qh);
            gather_rows(datt, base, d, head * hd, hd, 0..t_len, doh);

            // Pass 1: replay the streaming forward — rebuilds m/l and the
            // unnormalized accumulator; `O = oh/l` gives `D = Σ_j dO⊙O`
            // (= Σ_j P·dP rowsum, the softmax-backward inner product).
            stream_pair_forward(
                qkv, base, w3, ko, vo, t_len, hd, scale, tc, qh, kt, vt, oh, tmp, pt, m, l, ch,
            );
            for t1 in 0..t_len {
                let inv = 1.0 / l[t1];
                let mut dsv = 0f32;
                for (&ov, &dov) in oh[t1 * hd..(t1 + 1) * hd].iter().zip(&doh[t1 * hd..]) {
                    dsv += ov * inv * dov;
                }
                dsum[t1] = dsv;
            }
            for x in dqh.iter_mut() {
                *x = 0.0;
            }

            // Pass 2: per K/V tile, rebuild the probability panel from the
            // final statistics and consume it immediately.
            let mut j0 = 0usize;
            while j0 < t_len {
                let jlen = tc.min(t_len - j0);
                gather_rows(qkv, base, w3, ko, hd, j0..j0 + jlen, kt);
                gather_rows(qkv, base, w3, vo, hd, j0..j0 + jlen, vt);
                let ra = t_len - j0;
                let p = &mut pt[..ra * jlen];
                kernels::matmul_nt_f32(
                    &qh[j0 * hd..t_len * hd],
                    &kt[..jlen * hd],
                    ra,
                    hd,
                    jlen,
                    p,
                );
                // P_ij = exp(scale·S_ij − m_i) / l_i on the causal support.
                for i in 0..ra {
                    let t1 = j0 + i;
                    let vis = jlen.min(i + 1);
                    let (mi, inv_l) = (m[t1], 1.0 / l[t1]);
                    let prow = &mut p[i * jlen..(i + 1) * jlen];
                    simd::exp_recompute(&mut prow[..vis], scale, mi, inv_l);
                    for s in prow[vis..].iter_mut() {
                        *s = 0.0;
                    }
                }
                // dV_tile = Pᵀ·dO over the active rows.
                for x in dvt[..jlen * hd].iter_mut() {
                    *x = 0.0;
                }
                kernels::matmul_tn_acc_f32(
                    p,
                    &doh[j0 * hd..t_len * hd],
                    ra,
                    jlen,
                    hd,
                    &mut dvt[..jlen * hd],
                );
                // dP_tile = dO·V_tileᵀ.
                kernels::matmul_nt_f32(
                    &doh[j0 * hd..t_len * hd],
                    &vt[..jlen * hd],
                    ra,
                    hd,
                    jlen,
                    &mut dpt[..ra * jlen],
                );
                // dS = P ⊙ (dP − D) · scale, written over the P panel
                // (masked entries are already 0 there and stay 0).
                for i in 0..ra {
                    let t1 = j0 + i;
                    let vis = jlen.min(i + 1);
                    let dsv = dsum[t1];
                    let prow = &mut p[i * jlen..(i + 1) * jlen];
                    for (s, &dp) in prow[..vis].iter_mut().zip(&dpt[i * jlen..]) {
                        *s *= (dp - dsv) * scale;
                    }
                }
                // dQ[j0..] += dS·K_tile (staged through tmp — the pooled
                // matmul overwrites its output).
                kernels::matmul_f32(p, &kt[..jlen * hd], ra, jlen, hd, &mut tmp[..ra * hd]);
                for (dq, &tv) in dqh[j0 * hd..t_len * hd].iter_mut().zip(&tmp[..ra * hd]) {
                    *dq += tv;
                }
                // dK_tile = dSᵀ·Q over the active rows.
                for x in dkt[..jlen * hd].iter_mut() {
                    *x = 0.0;
                }
                kernels::matmul_tn_acc_f32(
                    p,
                    &qh[j0 * hd..t_len * hd],
                    ra,
                    jlen,
                    hd,
                    &mut dkt[..jlen * hd],
                );
                // Each key row lives in exactly one tile: scatter dK/dV now.
                for (jj, t2) in (j0..j0 + jlen).enumerate() {
                    let row = (base + t2) * w3;
                    // SAFETY: pair (b, head) owns the k/v column ranges of
                    // its head within rows [base, base + t_len) — disjoint
                    // across pairs; each (pair, key row) is written once.
                    let (dk, dv) = unsafe {
                        (
                            std::slice::from_raw_parts_mut(dqkv_ptr.0.add(row + ko), hd),
                            std::slice::from_raw_parts_mut(dqkv_ptr.0.add(row + vo), hd),
                        )
                    };
                    dk.copy_from_slice(&dkt[jj * hd..(jj + 1) * hd]);
                    dv.copy_from_slice(&dvt[jj * hd..(jj + 1) * hd]);
                }
                j0 += jlen;
            }
            for t1 in 0..t_len {
                let row = (base + t1) * w3;
                // SAFETY: as above — pair-owned query columns, written once.
                let dq = unsafe { std::slice::from_raw_parts_mut(dqkv_ptr.0.add(row + qo), hd) };
                dq.copy_from_slice(&dqh[t1 * hd..(t1 + 1) * hd]);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Plain scalar causal softmax-attention recurrence — the oracle the
    /// blocked formulation must reproduce (f32 tolerance: the kernels
    /// re-associate the dot/axpy sums).
    fn scalar_reference(qkv: &[f32], batch: usize, t_len: usize, d: usize, heads: usize) -> Vec<f32> {
        let hd = d / heads;
        let w3 = 3 * d;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut att = vec![0f32; batch * t_len * d];
        for b in 0..batch {
            let base = b * t_len;
            for head in 0..heads {
                let (qo, ko, vo) = (head * hd, d + head * hd, 2 * d + head * hd);
                for t1 in 0..t_len {
                    let q = &qkv[(base + t1) * w3 + qo..(base + t1) * w3 + qo + hd];
                    let mut sc = vec![0f32; t1 + 1];
                    let mut mx = f32::NEG_INFINITY;
                    for t2 in 0..=t1 {
                        let k = &qkv[(base + t2) * w3 + ko..(base + t2) * w3 + ko + hd];
                        sc[t2] = q.iter().zip(k).map(|(a, b)| a * b).sum::<f32>() * scale;
                        mx = mx.max(sc[t2]);
                    }
                    let mut sum = 0f32;
                    for v in sc.iter_mut() {
                        *v = (*v - mx).exp();
                        sum += *v;
                    }
                    for j in 0..hd {
                        let mut o = 0f32;
                        for (t2, w) in sc.iter().enumerate() {
                            o += w / sum * qkv[(base + t2) * w3 + vo + j];
                        }
                        att[(base + t1) * d + head * hd + j] = o;
                    }
                }
            }
        }
        att
    }

    #[test]
    fn property_blocked_attention_matches_scalar_reference() {
        // Randomized (batch, heads, head width, seq, slot count): the pooled
        // head-parallel path and the probs-retaining path must both agree
        // with the scalar recurrence, and retained probs rows must be causal
        // distributions.  (The three-way streaming ≡ blocked ≡ scalar grid
        // lives in tests/attention_equivalence.rs.)
        crate::prop::forall(
            610,
            40,
            |rng| {
                let batch = 1 + rng.below(3);
                let heads = 1 + rng.below(4);
                let hd = 1 + rng.below(6);
                let t_len = 1 + rng.below(12);
                let slots = 1 + rng.below(8);
                let d = heads * hd;
                let qkv: Vec<f32> =
                    (0..batch * t_len * 3 * d).map(|_| rng.normal() as f32).collect();
                (batch, heads, t_len, slots, qkv)
            },
            |(batch, heads, t_len, slots, qkv)| {
                let (batch, heads, t_len) = (*batch, *heads, *t_len);
                let d = qkv.len() / (batch * t_len * 3);
                let hd = d / heads;
                let want = scalar_reference(qkv, batch, t_len, d, heads);

                let mut ws = AttnWorkspace::new(t_len, hd, *slots);
                let mut att = vec![0f32; batch * t_len * d];
                causal_attention(qkv, batch, t_len, d, heads, &mut ws, &mut att, None);
                for (i, (g, w)) in att.iter().zip(&want).enumerate() {
                    if (g - w).abs() > 1e-4 {
                        return Err(format!("discard-probs att[{i}]: {g} vs {w}"));
                    }
                }

                let mut probs = vec![0f32; batch * heads * t_len * t_len];
                let mut att2 = vec![0f32; batch * t_len * d];
                causal_attention(qkv, batch, t_len, d, heads, &mut ws, &mut att2, Some(&mut probs));
                if att != att2 {
                    return Err("probs-retaining path changed the output".into());
                }
                for (pair, mat) in probs.chunks_exact(t_len * t_len).enumerate() {
                    for t1 in 0..t_len {
                        let row = &mat[t1 * t_len..(t1 + 1) * t_len];
                        let s: f32 = row[..=t1].iter().sum();
                        if (s - 1.0).abs() > 1e-4 {
                            return Err(format!("pair {pair} row {t1} sums to {s}"));
                        }
                        if row[t1 + 1..].iter().any(|&x| x != 0.0) {
                            return Err(format!("pair {pair} row {t1} leaks future keys"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn streaming_matches_blocked_basic() {
        // Smoke-level streaming ≡ blocked check (the randomized grid with
        // adversarial shapes lives in tests/attention_equivalence.rs).
        let (batch, heads, hd, t_len) = (2usize, 3usize, 5usize, 17usize);
        let d = heads * hd;
        let mut rng = Rng::new(613);
        let qkv: Vec<f32> = (0..batch * t_len * 3 * d).map(|_| rng.normal() as f32).collect();
        let mut att_b = vec![0f32; batch * t_len * d];
        let mut att_s = vec![0f32; batch * t_len * d];
        let mut ws_b = AttnWorkspace::new(t_len, hd, 2);
        causal_attention(&qkv, batch, t_len, d, heads, &mut ws_b, &mut att_b, None);
        for tile in [1usize, 4, 7, 17, 32] {
            let mut ws_s = AttnWorkspace::new_streaming(t_len, hd, 3, tile);
            assert!(ws_s.is_streaming());
            causal_attention(&qkv, batch, t_len, d, heads, &mut ws_s, &mut att_s, None);
            for (i, (s, b)) in att_s.iter().zip(&att_b).enumerate() {
                assert!(
                    (s - b).abs() < 1e-5 * 1.0f32.max(b.abs()),
                    "tile {tile} att[{i}]: streaming {s} vs blocked {b}"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "probs retention requires a blocked workspace")]
    fn streaming_workspace_rejects_probs_retention() {
        let (batch, heads, hd, t_len) = (1usize, 1usize, 2usize, 4usize);
        let d = heads * hd;
        let qkv = vec![0.1f32; batch * t_len * 3 * d];
        let mut att = vec![0f32; batch * t_len * d];
        let mut probs = vec![0f32; batch * heads * t_len * t_len];
        let mut ws = AttnWorkspace::new_streaming(t_len, hd, 1, 2);
        causal_attention(&qkv, batch, t_len, d, heads, &mut ws, &mut att, Some(&mut probs));
    }

    #[test]
    fn backward_matches_finite_difference_through_forward() {
        // Central-difference check of dL/dqkv for L = Σ c·att through the
        // shared forward/backward pair, across several slot counts.
        let (batch, heads, hd, t_len) = (2usize, 3usize, 4usize, 5usize);
        let d = heads * hd;
        let mut rng = Rng::new(611);
        let mut qkv: Vec<f32> = (0..batch * t_len * 3 * d).map(|_| rng.normal() as f32).collect();
        let coef: Vec<f32> = (0..batch * t_len * d).map(|_| rng.normal() as f32).collect();

        let loss = |qkv: &[f32], ws: &mut AttnWorkspace| -> f32 {
            let mut att = vec![0f32; batch * t_len * d];
            causal_attention(qkv, batch, t_len, d, heads, ws, &mut att, None);
            att.iter().zip(&coef).map(|(a, c)| a * c).sum()
        };

        for slots in [1usize, 3, 8] {
            let mut ws = AttnWorkspace::new(t_len, hd, slots);
            let mut gws = AttnGradWorkspace::new(t_len, hd, slots);
            let mut att = vec![0f32; batch * t_len * d];
            let mut probs = vec![0f32; batch * heads * t_len * t_len];
            causal_attention(&qkv, batch, t_len, d, heads, &mut ws, &mut att, Some(&mut probs));
            let mut dqkv = vec![0f32; batch * t_len * 3 * d];
            causal_attention_backward(
                &qkv, &probs, &coef, batch, t_len, d, heads, &mut gws, &mut dqkv,
            );

            let eps = 1e-2f32;
            for idx in [0usize, 7, 3 * d - 1, batch * t_len * 3 * d - 5] {
                let orig = qkv[idx];
                qkv[idx] = orig + eps;
                let lp = loss(&qkv, &mut ws);
                qkv[idx] = orig - eps;
                let lm = loss(&qkv, &mut ws);
                qkv[idx] = orig;
                let num = (lp - lm) / (2.0 * eps);
                assert!(
                    (num - dqkv[idx]).abs() < 2e-2 + 0.05 * dqkv[idx].abs(),
                    "slots {slots} dqkv[{idx}]: numeric {num} vs analytic {}",
                    dqkv[idx]
                );
            }
        }
    }

    #[test]
    fn streaming_backward_matches_retained_backward() {
        // The recompute-based streaming backward must reproduce the
        // retained-probs backward to f32 rounding, across tiles and slots
        // (the tiny cross-path pin; the full grid + finite differences
        // live in tests/attention_equivalence.rs).
        let (batch, heads, hd, t_len) = (2usize, 2usize, 3usize, 11usize);
        let d = heads * hd;
        let mut rng = Rng::new(614);
        let qkv: Vec<f32> = (0..batch * t_len * 3 * d).map(|_| rng.normal() as f32).collect();
        let datt: Vec<f32> = (0..batch * t_len * d).map(|_| rng.normal() as f32).collect();

        let mut ws = AttnWorkspace::new(t_len, hd, 2);
        let mut att = vec![0f32; batch * t_len * d];
        let mut probs = vec![0f32; batch * heads * t_len * t_len];
        causal_attention(&qkv, batch, t_len, d, heads, &mut ws, &mut att, Some(&mut probs));
        let mut want = vec![0f32; batch * t_len * 3 * d];
        let mut gws = AttnGradWorkspace::new(t_len, hd, 2);
        causal_attention_backward(
            &qkv, &probs, &datt, batch, t_len, d, heads, &mut gws, &mut want,
        );

        for (tile, slots) in [(1usize, 1usize), (4, 2), (5, 4), (11, 3), (16, 1)] {
            let mut sgws = AttnGradWorkspace::new_streaming(t_len, hd, slots, tile);
            let mut got = vec![0f32; batch * t_len * 3 * d];
            causal_attention_backward_streaming(
                &qkv, &datt, batch, t_len, d, heads, &mut sgws, &mut got,
            );
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() < 1e-4 * 1.0f32.max(w.abs()),
                    "tile {tile} slots {slots} dqkv[{i}]: streaming {g} vs retained {w}"
                );
            }
        }
    }

    #[test]
    fn workspace_never_reallocates_across_calls() {
        let (batch, heads, hd, t_len) = (2usize, 4usize, 8usize, 16usize);
        let d = heads * hd;
        let mut rng = Rng::new(612);
        let qkv: Vec<f32> = (0..batch * t_len * 3 * d).map(|_| rng.normal() as f32).collect();
        let mut att = vec![0f32; batch * t_len * d];
        for mut ws in [
            AttnWorkspace::new(t_len, hd, AttnWorkspace::auto_slots(batch * heads)),
            AttnWorkspace::new_streaming(t_len, hd, AttnWorkspace::auto_slots(batch * heads), 4),
        ] {
            causal_attention(&qkv, batch, t_len, d, heads, &mut ws, &mut att, None);
            let fp = ws.fingerprint();
            for _ in 0..4 {
                causal_attention(&qkv, batch, t_len, d, heads, &mut ws, &mut att, None);
            }
            assert_eq!(ws.fingerprint(), fp, "attention workspace must not reallocate");
        }
    }

    #[test]
    fn streaming_workspace_is_linear_in_seq() {
        // The no-(t, t)-buffer contract, as size accounting: the streaming
        // layout's largest per-slot panel is max(seq·hd, seq·tile) and the
        // total footprint scales linearly when seq doubles; the blocked
        // layout is quadratic.
        let (hd, tile, slots) = (16usize, 32usize, 2usize);
        for seq in [256usize, 512] {
            let s = AttnWorkspace::new_streaming(seq, hd, slots, tile);
            let b = AttnWorkspace::new(seq, hd, slots);
            assert_eq!(s.max_slot_panel_floats(), seq * tile.max(hd));
            assert!(s.max_slot_panel_floats() < seq * seq, "streaming panel must stay sub-(t,t)");
            assert_eq!(b.max_slot_panel_floats(), seq * seq);
            assert!(s.total_floats() < b.total_floats());
            let g = AttnGradWorkspace::new_streaming(seq, hd, slots, tile);
            assert_eq!(g.total_floats(), slots * stream_grad_stride(seq, hd, tile));
            assert!(g.total_floats() < AttnGradWorkspace::new(seq, hd, slots).total_floats());
        }
        // Doubling seq at most doubles the footprint (the K/V tile panels
        // are constant in seq, everything else is linear — nothing is
        // quadratic).  The blocked layout quadruples its score matrices.
        let s1 = AttnWorkspace::new_streaming(256, hd, slots, tile).total_floats();
        let s2 = AttnWorkspace::new_streaming(512, hd, slots, tile).total_floats();
        assert!(s2 <= 2 * s1, "streaming workspace must scale (sub-)linearly in seq: {s1} -> {s2}");
    }

    #[test]
    fn attn_path_resolution() {
        assert_eq!(AttnPath::Blocked.resolve(4096), None);
        assert_eq!(AttnPath::Streaming { tile: 32 }.resolve(8), Some(32));
        let auto = AttnPath::Auto { min_seq: 256, tile: 64 };
        assert_eq!(auto.resolve(255), None);
        assert_eq!(auto.resolve(256), Some(64));
        assert!(AttnWorkspace::with_path(512, 8, 1, AttnPath::auto_default()).is_streaming());
        assert!(!AttnWorkspace::with_path(64, 8, 1, AttnPath::auto_default()).is_streaming());
    }

    #[test]
    fn property_paged_decode_matches_scalar_reference() {
        // Randomized (heads, hd, t_len, page_size, pool slots): feeding a
        // sequence through the paged single-query kernel one position at a
        // time must reproduce the f64 scalar oracle at every position —
        // page sizes that do and don't divide t_len, t_len == 1, and a
        // single staging slot included.
        crate::prop::forall(
            1707,
            40,
            |rng| {
                let heads = 1 + rng.below(3);
                let hd = 1 + rng.below(9);
                let t_len = 1 + rng.below(25);
                let page = 1 + rng.below(t_len + 3);
                let slots = 1 + rng.below(4);
                let d = heads * hd;
                let qkv: Vec<f32> =
                    (0..t_len * 3 * d).map(|_| rng.normal() as f32).collect();
                (heads, t_len, page, slots, qkv)
            },
            |(heads, t_len, page, slots, qkv)| {
                let (heads, t_len, page, slots) = (*heads, *t_len, *page, *slots);
                let d = qkv.len() / (t_len * 3);
                let hd = d / heads;
                let want = scalar_reference(qkv, 1, t_len, d, heads);
                let mut cache = PagedKvCache::new(page, 1, heads, hd, 1, t_len, 0);
                let slot = cache.try_acquire(t_len).expect("full pool admits");
                let mut ws = DecodeWorkspace::new(hd, page, slots);
                let mut att = vec![0f32; t_len * d];
                let (mut row_slots, mut row_lens) = (vec![0usize; 1], vec![0usize; 1]);
                for pos in 0..t_len {
                    let row = &qkv[pos * 3 * d..(pos + 1) * 3 * d];
                    cache.write_kv(slot, 0, pos, &row[d..2 * d], &row[2 * d..3 * d]);
                    cache.advance(slot, 1);
                    row_slots[0] = slot;
                    row_lens[0] = pos + 1;
                    paged_decode_attention(
                        &cache,
                        row,
                        &row_slots,
                        &row_lens,
                        0,
                        d,
                        heads,
                        &mut ws,
                        &mut att[pos * d..(pos + 1) * d],
                    );
                }
                crate::prop::close(&att, &want, 1e-5)
            },
        );
    }
}
