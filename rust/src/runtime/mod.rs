//! Runtime — execution backends for the serving/training stack.
//!
//! * [`attention`] — the single causal attention implementation, shared by
//!   the serving and training forwards, head-parallel over the worker
//!   pool.  Two formulations behind one entry point: blocked ((t, t)
//!   scores, probs retained or discarded) and streaming/flash-style (tiled
//!   K/V, online softmax, nothing quadratic in seq; backward recomputes
//!   probs tile by tile), selected by the workspace layout at the
//!   config's sequence-length crossover.
//! * [`backend`] — the [`ServingBackend`] trait the coordinator, serving
//!   bench, and CLI dispatch through, including the prefill/decode seam for
//!   incremental generation.
//! * [`kvcache`] — the paged per-request K/V store behind the decode seam:
//!   fixed-size `(page_size × hd)` pages per (request, layer, head) from
//!   one preallocated pool, consumed tile-by-tile by the single-query
//!   decode kernel in [`attention`].
//! * [`native`] (default) — the pure-rust backend: GAR submodel forwards
//!   through `linalg::kernels` with a preallocated scratch arena.  This is
//!   what the coordinator, benches, and tests run on an offline machine.
//! * `engine` (feature `pjrt`) — the PJRT CPU client over the AOT artifacts
//!   (`make artifacts`, python build-time).  The hot path keeps parameters
//!   device-resident (`execute_b` over `xla::PjRtBuffer`) so train steps
//!   never round-trip weights through host memory (see DESIGN.md §Perf).
//!   Enabling `pjrt` requires the `xla` crate (see rust/Cargo.toml).

pub mod attention;
pub mod backend;
#[cfg(feature = "pjrt")]
mod engine;
pub mod kvcache;
pub mod manifest;
pub mod native;
mod tensor;

pub use backend::ServingBackend;
pub use kvcache::{PagedKvCache, DEFAULT_KV_PAGE_SIZE};
#[cfg(feature = "pjrt")]
pub use engine::{DeviceTensor, Engine, Executable};
pub use manifest::{ArtifactSpec, Manifest, ModelConfig, TensorSpec};
pub use tensor::{DType, Tensor};
