//! Runtime — load and execute the AOT artifacts via the PJRT CPU client.
//!
//! `make artifacts` (python, build-time) lowers every L2 entry point to HLO
//! text; this module is the only place that touches XLA at runtime.  The hot
//! path keeps parameters device-resident (`execute_b` over [`xla::PjRtBuffer`])
//! so train steps / serving requests never round-trip weights through host
//! memory (see DESIGN.md §Perf).

mod engine;
pub mod manifest;
mod tensor;

pub use engine::{DeviceTensor, Engine, Executable};
pub use manifest::{ArtifactSpec, Manifest, ModelConfig, TensorSpec};
pub use tensor::{DType, Tensor};
