//! Host-side tensor: the interchange type between rust logic and PJRT.

use anyhow::{bail, Result};

/// Element type of a [`Tensor`] (the repo only needs f32 + i32).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" | "f32" => Ok(DType::F32),
            "int32" | "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

/// A dense host tensor (row-major).
#[derive(Debug, Clone)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::I32 { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor::F32 { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn scalar_f32(x: f32) -> Self {
        Tensor::F32 { shape: vec![], data: vec![x] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } => DType::F32,
            Tensor::I32 { .. } => DType::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Scalar extraction (first element) for loss outputs.
    pub fn item_f32(&self) -> Result<f32> {
        Ok(self.as_f32()?[0])
    }

    /// Convert to an XLA literal of matching shape/dtype.
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
            Tensor::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        };
        Ok(lit)
    }

    /// Build from an XLA literal.
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
            xla::ElementType::S32 => Ok(Tensor::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back.shape(), &[2, 3]);
        assert_eq!(back.as_f32().unwrap(), t.as_f32().unwrap());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_i32() {
        let t = Tensor::i32(vec![4], vec![7, -1, 0, 3]);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back.as_i32().unwrap(), &[7, -1, 0, 3]);
    }

    #[test]
    fn scalar_item() {
        let t = Tensor::scalar_f32(2.5);
        assert_eq!(t.item_f32().unwrap(), 2.5);
        assert_eq!(t.shape(), &[] as &[usize]);
    }
}
