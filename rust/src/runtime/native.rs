//! Native execution backend: the GAR serving forward (python
//! `model.gar_fwd`, Sec. 3.5) implemented directly over
//! [`crate::linalg::kernels`] f32 paths — no PJRT, no artifacts.
//!
//! Semantics mirror the AOT graph exactly: token + position embeddings,
//! pre-LN blocks with causal multi-head attention (scale `1/√hd`), fused
//! GAR linears `y = [t, t·Ûᵀ] + b`, tanh-GELU MLP, final LN, tied logits
//! head `x · tok_embᵀ`.
//!
//! **Hot-path allocation discipline:** every activation intermediate lives
//! in a [`Scratch`] sized once at load time — [`GarSubmodel::forward`]
//! allocates no buffer memory per request (tests pin the buffer addresses
//! across calls), and the serving coordinator reuses one `Scratch` across
//! all batches and tiers.  Large kernels fan out over the persistent
//! worker pool (`linalg::pool`), so no threads are spawned on the path
//! either.
//!
//! **Attention:** the causal multi-head attention is the shared
//! implementation in [`crate::runtime::attention`] (panels gathered into an
//! [`AttnWorkspace`] held by `Scratch`, pooled `Q·Kᵀ`/`S·V`, head-parallel
//! over the worker pool) with softmax probs discarded.  The workspace
//! layout picks the formulation at load time: the streaming (flash-style)
//! tile at/above the config's `attn_streaming_min_seq` crossover — no
//! `(t, t)` score matrix, workspace linear in `seq` — and the blocked path
//! below it ([`crate::runtime::attention::AttnPath`]).

use anyhow::{ensure, Context, Result};

use crate::flexrank::gar::gar_solve;
use crate::linalg::kernels;
use crate::linalg::quant::{Precision, QuantMat};
use crate::linalg::AlignedVec;
use crate::runtime::attention::{
    causal_attention, paged_decode_attention, AttnPath, AttnWorkspace, DecodeWorkspace,
};
use crate::runtime::kvcache::PagedKvCache;
use crate::runtime::manifest::ModelConfig;
use crate::training::params::{ParamSet, LAYER_KINDS};

/// One GAR-form factorized linear: `y = [t, t·Ûᵀ] + b`, `t = x·Ṽ`.  The
/// factors are stored at the tier's [`Precision`] (f32 / bf16 / i8 with
/// per-column scales) and dequantized panel-wise inside the kernels;
/// activations and biases stay f32.
#[derive(Debug, Clone)]
pub struct GarLayerF32 {
    pub n: usize,
    pub m: usize,
    pub r: usize,
    /// (m − r, r); empty when r == m (square full-rank layer, Ũ = I).
    pub u_hat: QuantMat,
    /// (n, r)
    pub v_tilde: QuantMat,
    /// (m)
    pub bias: Vec<f32>,
}

impl GarLayerF32 {
    /// Inference parameter count of this layer (elements, independent of
    /// storage precision).
    pub fn n_params(&self) -> usize {
        self.u_hat.n_elems() + self.v_tilde.n_elems() + self.bias.len()
    }

    /// Bytes the factor storage actually occupies at this precision.
    pub fn stored_bytes(&self) -> usize {
        self.u_hat.stored_bytes() + self.v_tilde.stored_bytes() + self.bias.len() * 4
    }

    /// Fused forward over `rows` input rows of width `n` (contiguous),
    /// writing `m` outputs per row at `y[row·stride + off ..]`.
    /// `t` is scratch for the `(rows × r)` intermediate.
    fn forward_into(
        &self,
        x: &[f32],
        rows: usize,
        t: &mut [f32],
        y: &mut [f32],
        stride: usize,
        off: usize,
    ) {
        let t = &mut t[..rows * self.r];
        kernels::matmul_f32_q(&x[..rows * self.n], &self.v_tilde, rows, self.n, self.r, t);
        kernels::gar_emit_f32_q(t, rows, self.r, &self.u_hat, y, stride, off);
        for i in 0..rows {
            let yrow = &mut y[i * stride + off..i * stride + off + self.m];
            for (o, &b) in yrow.iter_mut().zip(&self.bias) {
                *o += b;
            }
        }
    }
}

/// One transformer block's GAR parameters.
#[derive(Debug, Clone)]
pub struct NativeBlock {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub qkv: GarLayerF32,
    pub proj: GarLayerF32,
    pub fc: GarLayerF32,
    pub fcp: GarLayerF32,
}

/// A deployable GAR submodel at one rank profile.
#[derive(Debug, Clone)]
pub struct GarSubmodel {
    pub profile: Vec<usize>,
    /// Storage precision of every factorized layer's Û/Ṽ.
    pub precision: Precision,
    pub n_params: usize,
    pub d: usize,
    pub heads: usize,
    pub seq: usize,
    pub vocab: usize,
    tok_emb: Vec<f32>,
    pos_emb: Vec<f32>,
    lnf_g: Vec<f32>,
    lnf_b: Vec<f32>,
    blocks: Vec<NativeBlock>,
}

/// Preallocated activation workspace for up to `max_rows = batch·seq` token
/// rows.  All buffers are written before being read on every forward — no
/// zeroing between requests, no growth after construction.
#[derive(Debug)]
pub struct Scratch {
    pub max_rows: usize,
    x: AlignedVec<f32>,   // (rows, d)   residual stream
    a: AlignedVec<f32>,   // (rows, d)   LN output / layer output staging
    t: AlignedVec<f32>,   // (rows, r≤d) factor intermediate
    qkv: AlignedVec<f32>, // (rows, 3d)
    att: AlignedVec<f32>, // (rows, d)   merged attention heads
    ff: AlignedVec<f32>,  // (rows, 4d)
    attn: AttnWorkspace,  // shared blocked-attention panels (per pool slot)
    logits: AlignedVec<f32>, // (rows, vocab)
}

impl Scratch {
    /// Scratch with the built-in attention crossover defaults (streaming
    /// at/above [`crate::runtime::attention::DEFAULT_STREAMING_MIN_SEQ`]).
    pub fn new(max_rows: usize, d: usize, heads: usize, seq: usize, vocab: usize) -> Scratch {
        Scratch::with_attn(max_rows, d, heads, seq, vocab, AttnPath::auto_default())
    }

    /// Scratch honoring a config's `attn_tile` / `attn_streaming_min_seq`
    /// knobs — what the serving registry loads through.
    pub fn for_config(cfg: &ModelConfig, max_rows: usize) -> Scratch {
        Scratch::with_attn(max_rows, cfg.d_model, cfg.n_heads, cfg.seq_len, cfg.vocab, cfg.attn_path())
    }

    /// Scratch with an explicit attention path (tests pin both formulations
    /// regardless of the sequence-length crossover).
    pub fn with_attn(
        max_rows: usize,
        d: usize,
        heads: usize,
        seq: usize,
        vocab: usize,
        path: AttnPath,
    ) -> Scratch {
        let hd = d / heads.max(1);
        let max_batch = if seq > 0 { (max_rows / seq).max(1) } else { 1 };
        let slots = AttnWorkspace::auto_slots(max_batch * heads.max(1));
        Scratch {
            max_rows,
            x: AlignedVec::zeroed(max_rows * d),
            a: AlignedVec::zeroed(max_rows * d),
            t: AlignedVec::zeroed(max_rows * d),
            qkv: AlignedVec::zeroed(max_rows * 3 * d),
            att: AlignedVec::zeroed(max_rows * d),
            ff: AlignedVec::zeroed(max_rows * 4 * d),
            attn: AttnWorkspace::with_path(seq, hd, slots, path),
            logits: AlignedVec::zeroed(max_rows * vocab),
        }
    }

    /// Whether forwards through this scratch run the streaming attention.
    pub fn attn_is_streaming(&self) -> bool {
        self.attn.is_streaming()
    }

    /// Attention-path tag for bench/log lines ("blocked",
    /// "streaming(tile=64)", …).
    pub fn attn_path_label(&self) -> String {
        self.attn.path_label()
    }

    /// Largest per-slot attention panel in f32 elements — the streaming
    /// serving path's no-`(t, t)`-buffer contract is asserted against this.
    pub fn attn_max_slot_panel_floats(&self) -> usize {
        self.attn.max_slot_panel_floats()
    }

    /// Logits of the last forward: `(rows, vocab)` row-major.
    pub fn logits(&self, rows: usize, vocab: usize) -> &[f32] {
        &self.logits[..rows * vocab]
    }

    /// Buffer base pointers — lets tests assert that repeated forwards
    /// never reallocate (the zero-per-request-allocation invariant).
    pub fn fingerprint(&self) -> Vec<usize> {
        let mut fp = vec![
            self.x.as_ptr() as usize,
            self.a.as_ptr() as usize,
            self.t.as_ptr() as usize,
            self.qkv.as_ptr() as usize,
            self.att.as_ptr() as usize,
            self.ff.as_ptr() as usize,
            self.logits.as_ptr() as usize,
        ];
        fp.extend(self.attn.fingerprint());
        fp
    }
}

/// Preallocated workspace for the incremental (prefill/decode) path: up to
/// `max_rows` active token rows per step — a whole prompt during prefill,
/// one row per in-flight request during decode.  Unlike [`Scratch`] there is
/// no monolithic `(seq × seq)`-capable attention workspace: attention state
/// lives in the caller's [`PagedKvCache`], and the only attention staging
/// here is one page-tile score row + accumulator per pool slot
/// ([`DecodeWorkspace`]).  All buffers are written before being read each
/// step — no zeroing between steps, no growth after construction.
#[derive(Debug)]
pub struct DecodeScratch {
    pub max_rows: usize,
    x: AlignedVec<f32>,   // (rows, d)   residual stream
    a: AlignedVec<f32>,   // (rows, d)   LN / layer output staging
    t: AlignedVec<f32>,   // (rows, r≤d) factor intermediate
    qkv: AlignedVec<f32>, // (rows, 3d)
    att: AlignedVec<f32>, // (rows, d)   merged attention heads
    ff: AlignedVec<f32>,  // (rows, 4d)
    dec: DecodeWorkspace, // per-pool-slot page-tile staging
    logits: AlignedVec<f32>, // (rows, vocab)
    /// Request slot per active row (filled each step, fixed length).
    row_slots: Vec<usize>,
    /// K/V length per active row (the row's position + 1).
    row_lens: Vec<usize>,
}

impl DecodeScratch {
    pub fn new(
        max_rows: usize,
        d: usize,
        heads: usize,
        vocab: usize,
        page_size: usize,
    ) -> DecodeScratch {
        let hd = d / heads.max(1);
        let slots = AttnWorkspace::auto_slots(max_rows * heads.max(1));
        DecodeScratch {
            max_rows,
            x: AlignedVec::zeroed(max_rows * d),
            a: AlignedVec::zeroed(max_rows * d),
            t: AlignedVec::zeroed(max_rows * d),
            qkv: AlignedVec::zeroed(max_rows * 3 * d),
            att: AlignedVec::zeroed(max_rows * d),
            ff: AlignedVec::zeroed(max_rows * 4 * d),
            dec: DecodeWorkspace::new(hd, page_size, slots),
            logits: AlignedVec::zeroed(max_rows * vocab),
            row_slots: vec![0; max_rows],
            row_lens: vec![0; max_rows],
        }
    }

    /// Sized for a config's serving shape: prefill of a full `seq_len`
    /// prompt or one decode row per `batch_serve` slot, whichever is wider.
    pub fn for_config(cfg: &ModelConfig) -> DecodeScratch {
        DecodeScratch::new(
            cfg.seq_len.max(cfg.batch_serve),
            cfg.d_model,
            cfg.n_heads,
            cfg.vocab,
            cfg.kv_page_size,
        )
    }

    /// Logits of the last prefill/decode step: `(rows, vocab)` row-major,
    /// one row per active token in step order.
    pub fn logits(&self, rows: usize, vocab: usize) -> &[f32] {
        &self.logits[..rows * vocab]
    }

    /// Buffer base pointers — the decode loop's zero-allocation pin.
    pub fn fingerprint(&self) -> Vec<usize> {
        let mut fp = vec![
            self.x.as_ptr() as usize,
            self.a.as_ptr() as usize,
            self.t.as_ptr() as usize,
            self.qkv.as_ptr() as usize,
            self.att.as_ptr() as usize,
            self.ff.as_ptr() as usize,
            self.logits.as_ptr() as usize,
            self.row_slots.as_ptr() as usize,
            self.row_lens.as_ptr() as usize,
        ];
        fp.extend(self.dec.fingerprint());
        fp
    }
}

fn layer_norm(x: &[f32], rows: usize, d: usize, g: &[f32], b: &[f32], out: &mut [f32]) {
    for i in 0..rows {
        let xr = &x[i * d..(i + 1) * d];
        let or = &mut out[i * d..(i + 1) * d];
        let mu = xr.iter().sum::<f32>() / d as f32;
        let var = xr.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for ((o, &xv), (&gv, &bv)) in or.iter_mut().zip(xr).zip(g.iter().zip(b)) {
            *o = (xv - mu) * inv * gv + bv;
        }
    }
}

fn gelu(x: &mut [f32]) {
    for v in x.iter_mut() {
        let z = *v;
        *v = 0.5 * z * (1.0 + (0.7978845608028654 * (z + 0.044715 * z * z * z)).tanh());
    }
}

fn add_assign(dst: &mut [f32], src: &[f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

impl GarSubmodel {
    /// Re-gauge a consolidated student's factors at `profile` with f32
    /// factor storage (one rank per factorized layer, canonical block-major
    /// order).
    pub fn from_student(cfg: &ModelConfig, student: &ParamSet, profile: &[usize]) -> Result<GarSubmodel> {
        GarSubmodel::from_student_prec(cfg, student, profile, Precision::F32)
    }

    /// Re-gauge a consolidated student's factors at `profile`, storing the
    /// per-layer Û/Ṽ factors quantized at `prec` (the re-gauge itself runs
    /// in f64 and is quantized once at load time).
    pub fn from_student_prec(
        cfg: &ModelConfig,
        student: &ParamSet,
        profile: &[usize],
        prec: Precision,
    ) -> Result<GarSubmodel> {
        ensure!(
            profile.len() == cfg.n_fact_layers(),
            "profile has {} entries, model has {} factorized layers",
            profile.len(),
            cfg.n_fact_layers()
        );
        // d_model/n_heads divisibility is validated once at ModelConfig
        // load time (a bad config fails at parse, not first forward).
        let vec1 = |name: &str| -> Result<Vec<f32>> { Ok(student.get(name)?.as_f32()?.to_vec()) };

        let dims = cfg.layer_dims();
        let mut blocks = Vec::with_capacity(cfg.n_blocks);
        for b in 0..cfg.n_blocks {
            let lay = |kind: &str, ki: usize| -> Result<GarLayerF32> {
                let (_, n, m) = dims[ki];
                let r = profile[b * 4 + ki].clamp(1, cfg.rank_full().min(m).min(n.min(m)));
                let u = student.mat(&format!("blocks.{b}.{kind}_u"))?;
                let v = student.mat(&format!("blocks.{b}.{kind}_v"))?;
                let gar = gar_solve(&u, &v, r)
                    .with_context(|| format!("GAR re-gauge blocks.{b}.{kind} at r={r}"))?;
                Ok(GarLayerF32 {
                    n,
                    m,
                    r,
                    u_hat: QuantMat::from_f32(&gar.u_hat.to_f32(), m - r, r, prec),
                    v_tilde: QuantMat::from_f32(&gar.v_tilde.to_f32(), n, r, prec),
                    bias: vec1(&format!("blocks.{b}.{kind}_b"))?,
                })
            };
            let mut layers = Vec::with_capacity(4);
            for (ki, kind) in LAYER_KINDS.iter().enumerate() {
                layers.push(lay(kind, ki)?);
            }
            let fcp = layers.pop().unwrap();
            let fc = layers.pop().unwrap();
            let proj = layers.pop().unwrap();
            let qkv = layers.pop().unwrap();
            blocks.push(NativeBlock {
                ln1_g: vec1(&format!("blocks.{b}.ln1_g"))?,
                ln1_b: vec1(&format!("blocks.{b}.ln1_b"))?,
                ln2_g: vec1(&format!("blocks.{b}.ln2_g"))?,
                ln2_b: vec1(&format!("blocks.{b}.ln2_b"))?,
                qkv,
                proj,
                fc,
                fcp,
            });
        }

        let tok_emb = vec1("tok_emb")?;
        let pos_emb = vec1("pos_emb")?;
        let lnf_g = vec1("lnf_g")?;
        let lnf_b = vec1("lnf_b")?;
        let n_params = tok_emb.len()
            + pos_emb.len()
            + lnf_g.len()
            + lnf_b.len()
            + blocks
                .iter()
                .map(|blk| {
                    blk.ln1_g.len()
                        + blk.ln1_b.len()
                        + blk.ln2_g.len()
                        + blk.ln2_b.len()
                        + blk.qkv.n_params()
                        + blk.proj.n_params()
                        + blk.fc.n_params()
                        + blk.fcp.n_params()
                })
                .sum::<usize>();
        Ok(GarSubmodel {
            profile: profile.to_vec(),
            precision: prec,
            n_params,
            d: cfg.d_model,
            heads: cfg.n_heads,
            seq: cfg.seq_len,
            vocab: cfg.vocab,
            tok_emb,
            pos_emb,
            lnf_g,
            lnf_b,
            blocks,
        })
    }

    /// Forward `batch` sequences of `seq` tokens; logits land in
    /// `scratch.logits`.  Allocation-free: every buffer is preallocated in
    /// `scratch` and fully overwritten.
    pub fn forward(&self, tokens: &[i32], batch: usize, s: &mut Scratch) -> Result<()> {
        self.forward_window(tokens, batch, self.seq, s)
    }

    /// Forward `batch` sequences of `t_len ≤ seq` tokens each (positions
    /// `0..t_len`) — the one-shot window the incremental prefill/decode
    /// path is pinned against, and the reference semantics for requests
    /// shorter than the serving window.
    pub fn forward_window(
        &self,
        tokens: &[i32],
        batch: usize,
        t_len: usize,
        s: &mut Scratch,
    ) -> Result<()> {
        let rows = batch * t_len;
        let d = self.d;
        ensure!(
            t_len > 0 && t_len <= self.seq,
            "window of {t_len} tokens outside the model's 1..={} range",
            self.seq
        );
        ensure!(tokens.len() == rows, "expected {} tokens, got {}", rows, tokens.len());
        ensure!(rows <= s.max_rows, "scratch sized for {} rows, need {rows}", s.max_rows);

        // Embeddings: x = tok_emb[token] + pos_emb[position].  Reject
        // out-of-range ids loudly instead of aliasing them to a wrong row.
        for (i, &tok) in tokens.iter().enumerate() {
            ensure!(
                tok >= 0 && (tok as usize) < self.vocab,
                "token {tok} at position {i} outside vocab {}",
                self.vocab
            );
            let pos = i % t_len;
            let tv = &self.tok_emb[tok as usize * d..tok as usize * d + d];
            let pv = &self.pos_emb[pos * d..pos * d + d];
            let xr = &mut s.x[i * d..(i + 1) * d];
            for ((o, &a), &b) in xr.iter_mut().zip(tv).zip(pv) {
                *o = a + b;
            }
        }

        for blk in &self.blocks {
            // Attention half: x += proj(attn(qkv(ln1(x)))).
            layer_norm(&s.x, rows, d, &blk.ln1_g, &blk.ln1_b, &mut s.a);
            blk.qkv.forward_into(&s.a, rows, &mut s.t, &mut s.qkv, 3 * d, 0);
            causal_attention(
                &s.qkv,
                batch,
                t_len,
                d,
                self.heads,
                &mut s.attn,
                &mut s.att[..rows * d],
                None,
            );
            blk.proj.forward_into(&s.att, rows, &mut s.t, &mut s.a, d, 0);
            add_assign(&mut s.x[..rows * d], &s.a[..rows * d]);

            // MLP half: x += fcp(gelu(fc(ln2(x)))).
            layer_norm(&s.x, rows, d, &blk.ln2_g, &blk.ln2_b, &mut s.a);
            blk.fc.forward_into(&s.a, rows, &mut s.t, &mut s.ff, 4 * d, 0);
            gelu(&mut s.ff[..rows * 4 * d]);
            blk.fcp.forward_into(&s.ff, rows, &mut s.t, &mut s.a, d, 0);
            add_assign(&mut s.x[..rows * d], &s.a[..rows * d]);
        }

        // Final LN + tied head: logits = ln_f(x) · tok_embᵀ.
        layer_norm(&s.x, rows, d, &self.lnf_g, &self.lnf_b, &mut s.a);
        kernels::matmul_nt_f32(
            &s.a[..rows * d],
            &self.tok_emb,
            rows,
            d,
            self.vocab,
            &mut s.logits[..rows * self.vocab],
        );
        Ok(())
    }

    /// Prefill: run a whole prompt through the incremental path, appending
    /// its K/V rows to `slot`'s paged stream and leaving one logits row per
    /// prompt position in `s.logits`.  The slot must have been acquired
    /// with capacity for the prompt (plus any tokens to be decoded after
    /// it).  Equivalent to [`forward_window`] at the prompt length —
    /// the decode-equivalence suite pins the two to f32 rounding.
    ///
    /// [`forward_window`]: GarSubmodel::forward_window
    pub fn prefill(
        &self,
        tokens: &[i32],
        slot: usize,
        cache: &mut PagedKvCache,
        s: &mut DecodeScratch,
    ) -> Result<()> {
        let rows = tokens.len();
        ensure!(rows > 0, "empty prompt");
        ensure!(rows <= s.max_rows, "decode scratch sized for {} rows, need {rows}", s.max_rows);
        let start = cache.len(slot);
        ensure!(
            start + rows <= cache.capacity(slot),
            "prompt of {rows} tokens overruns slot {slot}'s reservation \
             ({start} cached, capacity {})",
            cache.capacity(slot)
        );
        for r in 0..rows {
            s.row_slots[r] = slot;
            s.row_lens[r] = start + r + 1;
        }
        self.forward_incremental(tokens, cache, s, rows)?;
        cache.advance(slot, rows);
        Ok(())
    }

    /// One continuous-batching decode step: row `r` holds the latest token
    /// of the request in cache slot `slots[r]` (sampled from the previous
    /// step's logits), appended at that stream's current length.  Leaves
    /// one logits row per request in `s.logits`, in `slots` order.  Each
    /// row's computation depends only on its own stream, so a request
    /// decodes bit-identically whatever batch composition it lands in —
    /// the property that makes continuous batching safe to verify against
    /// sequential replay.
    pub fn decode_step(
        &self,
        tokens: &[i32],
        slots: &[usize],
        cache: &mut PagedKvCache,
        s: &mut DecodeScratch,
    ) -> Result<()> {
        let rows = slots.len();
        ensure!(rows > 0, "empty decode step");
        ensure!(tokens.len() == rows, "{} tokens for {rows} slots", tokens.len());
        ensure!(rows <= s.max_rows, "decode scratch sized for {} rows, need {rows}", s.max_rows);
        for (r, &slot) in slots.iter().enumerate() {
            ensure!(
                cache.len(slot) < cache.capacity(slot),
                "slot {slot} decode overruns its reservation of {} tokens",
                cache.capacity(slot)
            );
            s.row_slots[r] = slot;
            s.row_lens[r] = cache.len(slot) + 1;
        }
        self.forward_incremental(tokens, cache, s, rows)?;
        for &slot in slots {
            cache.advance(slot, 1);
        }
        Ok(())
    }

    /// Shared body of prefill and decode: forward `rows` token rows whose
    /// (slot, position) assignments the caller staged in
    /// `s.row_slots`/`s.row_lens`, each block appending its K/V rows to the
    /// paged cache before attending over it.  Allocation-free: every
    /// intermediate lives in `s` or the cache pool.
    fn forward_incremental(
        &self,
        tokens: &[i32],
        cache: &mut PagedKvCache,
        s: &mut DecodeScratch,
        rows: usize,
    ) -> Result<()> {
        let d = self.d;
        for r in 0..rows {
            let tok = tokens[r];
            ensure!(
                tok >= 0 && (tok as usize) < self.vocab,
                "token {tok} at decode row {r} outside vocab {}",
                self.vocab
            );
            let pos = s.row_lens[r] - 1;
            ensure!(
                pos < self.seq,
                "position {pos} outside the learned positional table of {} entries",
                self.seq
            );
            let tv = &self.tok_emb[tok as usize * d..tok as usize * d + d];
            let pv = &self.pos_emb[pos * d..pos * d + d];
            let xr = &mut s.x[r * d..(r + 1) * d];
            for ((o, &a), &b) in xr.iter_mut().zip(tv).zip(pv) {
                *o = a + b;
            }
        }

        for (li, blk) in self.blocks.iter().enumerate() {
            // Attention half: x += proj(attn(qkv(ln1(x)))), with K/V read
            // from (and this step's rows appended to) the paged cache.
            layer_norm(&s.x, rows, d, &blk.ln1_g, &blk.ln1_b, &mut s.a);
            blk.qkv.forward_into(&s.a, rows, &mut s.t, &mut s.qkv, 3 * d, 0);
            for r in 0..rows {
                let qrow = &s.qkv[r * 3 * d..(r + 1) * 3 * d];
                cache.write_kv(
                    s.row_slots[r],
                    li,
                    s.row_lens[r] - 1,
                    &qrow[d..2 * d],
                    &qrow[2 * d..3 * d],
                );
            }
            paged_decode_attention(
                cache,
                &s.qkv,
                &s.row_slots[..rows],
                &s.row_lens[..rows],
                li,
                d,
                self.heads,
                &mut s.dec,
                &mut s.att[..rows * d],
            );
            blk.proj.forward_into(&s.att, rows, &mut s.t, &mut s.a, d, 0);
            add_assign(&mut s.x[..rows * d], &s.a[..rows * d]);

            // MLP half: x += fcp(gelu(fc(ln2(x)))).
            layer_norm(&s.x, rows, d, &blk.ln2_g, &blk.ln2_b, &mut s.a);
            blk.fc.forward_into(&s.a, rows, &mut s.t, &mut s.ff, 4 * d, 0);
            gelu(&mut s.ff[..rows * 4 * d]);
            blk.fcp.forward_into(&s.ff, rows, &mut s.t, &mut s.a, d, 0);
            add_assign(&mut s.x[..rows * d], &s.a[..rows * d]);
        }

        // Final LN + tied head: logits = ln_f(x) · tok_embᵀ.
        layer_norm(&s.x, rows, d, &self.lnf_g, &self.lnf_b, &mut s.a);
        kernels::matmul_nt_f32(
            &s.a[..rows * d],
            &self.tok_emb,
            rows,
            d,
            self.vocab,
            &mut s.logits[..rows * self.vocab],
        );
        Ok(())
    }
}

/// Uniform rank for a budget fraction: `round(budget · rank_full)`,
/// clamped to `[1, rank_full]`.  Nearby budgets can round to the same rank
/// — callers that need distinct tiers (the serving registry) must dedupe.
pub fn uniform_budget_rank(cfg: &ModelConfig, budget: f64) -> usize {
    ((budget * cfg.rank_full() as f64).round() as usize).clamp(1, cfg.rank_full())
}

/// Uniform rank profile for a budget fraction: every factorized layer at
/// [`uniform_budget_rank`] (the serving default until a DP-selected
/// profile is plugged in).
pub fn uniform_budget_profile(cfg: &ModelConfig, budget: f64) -> Vec<usize> {
    vec![uniform_budget_rank(cfg, budget); cfg.n_fact_layers()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flexrank::gar::Gar;
    use crate::linalg::Mat;
    use crate::rng::Rng;
    use crate::training::params::{decompose_teacher, random_teacher, student_from_factors};

    fn tiny_cfg() -> ModelConfig {
        crate::config::load_model_config("tiny").expect("configs/model_tiny.json")
    }

    #[test]
    fn gar_layer_matches_f64_gar() {
        let mut rng = Rng::new(500);
        let (n, m, r) = (6, 9, 4);
        let gar = Gar {
            u_hat: Mat::randn(m - r, r, &mut rng),
            v_tilde: Mat::randn(n, r, &mut rng),
            rank: r,
        };
        let layer = GarLayerF32 {
            n,
            m,
            r,
            u_hat: QuantMat::from_f32(&gar.u_hat.to_f32(), m - r, r, Precision::F32),
            v_tilde: QuantMat::from_f32(&gar.v_tilde.to_f32(), n, r, Precision::F32),
            bias: vec![0.0; m],
        };
        let x = Mat::randn(5, n, &mut rng);
        let want = gar.forward(&x);
        let x32 = x.to_f32();
        let mut t = vec![0f32; 5 * r];
        let mut y = vec![0f32; 5 * m];
        layer.forward_into(&x32, 5, &mut t, &mut y, m, 0);
        for (g, w) in y.iter().zip(&want.data) {
            assert!(((*g as f64) - w).abs() < 1e-4 * (1.0 + w.abs()), "{g} vs {w}");
        }
    }

    // The blocked-attention ≡ scalar-reference pin lives with the single
    // shared implementation now: see the property test in
    // `crate::runtime::attention` (randomized batch/heads/seq/slots).

    #[test]
    fn native_forward_finite_and_allocation_free() {
        let cfg = tiny_cfg();
        let teacher = random_teacher(&cfg, 7);
        let factors = decompose_teacher(&cfg, &teacher, None).unwrap();
        let student = student_from_factors(&cfg, &teacher, &factors).unwrap();
        let profile = uniform_budget_profile(&cfg, 0.5);
        let sub = GarSubmodel::from_student(&cfg, &student, &profile).unwrap();

        let batch = 2;
        let mut scratch =
            Scratch::new(batch * cfg.seq_len, cfg.d_model, cfg.n_heads, cfg.seq_len, cfg.vocab);
        let tokens: Vec<i32> = (0..batch * cfg.seq_len).map(|i| (i % cfg.vocab) as i32).collect();

        sub.forward(&tokens, batch, &mut scratch).unwrap();
        let fp = scratch.fingerprint();
        let l1: Vec<f32> = scratch.logits(batch * cfg.seq_len, cfg.vocab).to_vec();
        assert!(l1.iter().all(|x| x.is_finite()), "non-finite logits");

        // Second forward: same buffers (zero per-request allocations) and,
        // on identical input, identical output.
        sub.forward(&tokens, batch, &mut scratch).unwrap();
        assert_eq!(scratch.fingerprint(), fp, "scratch must not reallocate");
        assert_eq!(scratch.logits(batch * cfg.seq_len, cfg.vocab), &l1[..]);
    }

    #[test]
    fn streaming_scratch_matches_blocked_and_stays_allocation_free() {
        // The serving forward through a streaming-attention Scratch must
        // produce the blocked path's logits (to f32 rounding), allocate
        // nothing per request, and hold no (t, t) attention panel.
        let cfg = tiny_cfg();
        let teacher = random_teacher(&cfg, 17);
        let factors = decompose_teacher(&cfg, &teacher, None).unwrap();
        let student = student_from_factors(&cfg, &teacher, &factors).unwrap();
        let sub =
            GarSubmodel::from_student(&cfg, &student, &uniform_budget_profile(&cfg, 0.5)).unwrap();

        let batch = 2;
        let rows = batch * cfg.seq_len;
        let tokens: Vec<i32> = (0..rows).map(|i| (i * 3 % cfg.vocab) as i32).collect();

        let mut blocked = Scratch::with_attn(
            rows, cfg.d_model, cfg.n_heads, cfg.seq_len, cfg.vocab, AttnPath::Blocked,
        );
        assert!(!blocked.attn_is_streaming());
        sub.forward(&tokens, batch, &mut blocked).unwrap();
        let want = blocked.logits(rows, cfg.vocab).to_vec();

        let mut streaming = Scratch::with_attn(
            rows,
            cfg.d_model,
            cfg.n_heads,
            cfg.seq_len,
            cfg.vocab,
            AttnPath::Streaming { tile: 4 },
        );
        assert!(streaming.attn_is_streaming());
        assert!(
            streaming.attn_max_slot_panel_floats() < cfg.seq_len * cfg.seq_len,
            "streaming scratch must not hold a (t, t) attention panel"
        );
        sub.forward(&tokens, batch, &mut streaming).unwrap();
        let fp = streaming.fingerprint();
        for (i, (g, w)) in streaming.logits(rows, cfg.vocab).iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() < 1e-3 * (1.0 + w.abs()),
                "logit {i}: streaming {g} vs blocked {w}"
            );
        }
        sub.forward(&tokens, batch, &mut streaming).unwrap();
        assert_eq!(streaming.fingerprint(), fp, "streaming scratch must not reallocate");
    }

    #[test]
    fn quantized_submodel_tracks_f32_logits() {
        // A tier loaded at bf16 / i8 factor storage must stay close to the
        // f32 tier's logits (quantization perturbs factors, not semantics),
        // reuse the identical forward path (same scratch fingerprint), and
        // actually shrink factor storage.
        let cfg = tiny_cfg();
        let teacher = random_teacher(&cfg, 23);
        let factors = decompose_teacher(&cfg, &teacher, None).unwrap();
        let student = student_from_factors(&cfg, &teacher, &factors).unwrap();
        let profile = uniform_budget_profile(&cfg, 0.5);
        let f32_sub = GarSubmodel::from_student(&cfg, &student, &profile).unwrap();

        let batch = 2;
        let rows = batch * cfg.seq_len;
        let tokens: Vec<i32> = (0..rows).map(|i| (i * 5 % cfg.vocab) as i32).collect();
        let mut s = Scratch::new(rows, cfg.d_model, cfg.n_heads, cfg.seq_len, cfg.vocab);
        f32_sub.forward(&tokens, batch, &mut s).unwrap();
        let want = s.logits(rows, cfg.vocab).to_vec();
        let f32_bytes: usize =
            f32_sub.blocks.iter().map(|b| b.qkv.stored_bytes() + b.proj.stored_bytes()).sum();

        for (prec, tol) in [(Precision::Bf16, 2e-2f32), (Precision::I8, 2e-1)] {
            let q = GarSubmodel::from_student_prec(&cfg, &student, &profile, prec).unwrap();
            assert_eq!(q.precision, prec);
            assert_eq!(q.n_params, f32_sub.n_params, "logical param count is precision-free");
            let q_bytes: usize =
                q.blocks.iter().map(|b| b.qkv.stored_bytes() + b.proj.stored_bytes()).sum();
            assert!(q_bytes < f32_bytes, "{prec:?} must shrink factor storage");
            q.forward(&tokens, batch, &mut s).unwrap();
            let fp = s.fingerprint();
            for (i, (g, w)) in s.logits(rows, cfg.vocab).iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() <= tol * (1.0 + w.abs()),
                    "{prec:?} logit {i}: {g} vs f32 {w}"
                );
            }
            // The quantized path must stay allocation-free across requests.
            q.forward(&tokens, batch, &mut s).unwrap();
            assert_eq!(s.fingerprint(), fp, "quantized forward must not reallocate");
        }
    }

    #[test]
    fn full_profile_beats_truncated_on_reconstruction() {
        // The full-rank GAR submodel reproduces the factorized student
        // exactly, so its logits differ from a heavily truncated tier's.
        let cfg = tiny_cfg();
        let teacher = random_teacher(&cfg, 11);
        let factors = decompose_teacher(&cfg, &teacher, None).unwrap();
        let student = student_from_factors(&cfg, &teacher, &factors).unwrap();
        let full = GarSubmodel::from_student(&cfg, &student, &uniform_budget_profile(&cfg, 1.0)).unwrap();
        let cut = GarSubmodel::from_student(&cfg, &student, &uniform_budget_profile(&cfg, 0.25)).unwrap();
        assert!(cut.n_params < full.n_params);

        let batch = 1;
        let mut s = Scratch::new(cfg.seq_len, cfg.d_model, cfg.n_heads, cfg.seq_len, cfg.vocab);
        let tokens: Vec<i32> = (0..cfg.seq_len).map(|i| (i * 7 % cfg.vocab) as i32).collect();
        full.forward(&tokens, batch, &mut s).unwrap();
        let lf = s.logits(cfg.seq_len, cfg.vocab).to_vec();
        cut.forward(&tokens, batch, &mut s).unwrap();
        let lc = s.logits(cfg.seq_len, cfg.vocab).to_vec();
        let diff: f32 = lf.iter().zip(&lc).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-3, "truncation should change logits (diff {diff})");
    }
}
