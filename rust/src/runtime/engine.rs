//! PJRT engine: compile-once, execute-many over the AOT artifacts.
//!
//! All artifacts are lowered with `return_tuple=True`, and the PJRT client
//! (xla_extension 0.5.1, `untuple_result` off) hands the whole result back as
//! **one tuple buffer**; [`Executable::run`]/[`run_b`] decompose it into
//! per-output tensors/literals.
//!
//! Hot-loop note (DESIGN.md §Perf): inputs that don't change across calls
//! (teacher params during consolidation, submodel weights during serving)
//! are uploaded once with [`Engine::to_device`] and passed as
//! [`xla::PjRtBuffer`]s via [`Executable::run_b`]; only the step-varying
//! tensors round-trip through host memory.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`); see
//! DESIGN.md for why serialized protos are rejected by xla_extension 0.5.1.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, ensure, Context, Result};

use super::manifest::{ArtifactSpec, Manifest};
use super::tensor::Tensor;

/// A compiled artifact plus its manifest spec.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host tensors; returns per-output host tensors.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.check_inputs(inputs)?;
        let lits = inputs.iter().map(|t| t.to_literal()).collect::<Result<Vec<_>>>()?;
        let out = self.exe.execute::<xla::Literal>(&lits)?;
        let parts = self.untuple(out)?;
        parts.iter().map(Tensor::from_literal).collect()
    }

    /// Execute with device buffers; returns per-output host literals.
    /// (PJRT returns one tuple buffer; elements only exist as host literals.)
    pub fn run_b(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        ensure!(
            inputs.len() == self.spec.inputs.len(),
            "{}: got {} buffers, expect {}",
            self.spec.name,
            inputs.len(),
            self.spec.inputs.len()
        );
        let out = self.exe.execute_b(inputs)?;
        self.untuple(out)
    }

    /// Execute with host literals; returns per-output host literals.
    /// Literal reuse avoids Tensor<->Literal conversions in tight loops.
    pub fn run_literals(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        ensure!(
            inputs.len() == self.spec.inputs.len(),
            "{}: got {} literals, expect {}",
            self.spec.name,
            inputs.len(),
            self.spec.inputs.len()
        );
        let out = self.exe.execute::<&xla::Literal>(inputs)?;
        self.untuple(out)
    }

    fn untuple(&self, out: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<xla::Literal>> {
        let replica = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{}: empty execution result", self.spec.name))?;
        ensure!(!replica.is_empty(), "{}: no output buffers", self.spec.name);
        // return_tuple=True => exactly one tuple buffer.
        let lit = replica[0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        ensure!(
            parts.len() == self.spec.outputs.len(),
            "{}: got {} outputs, manifest says {}",
            self.spec.name,
            parts.len(),
            self.spec.outputs.len()
        );
        Ok(parts)
    }

    fn check_inputs(&self, inputs: &[Tensor]) -> Result<()> {
        ensure!(
            inputs.len() == self.spec.inputs.len(),
            "{}: got {} inputs, expect {}",
            self.spec.name,
            inputs.len(),
            self.spec.inputs.len()
        );
        for (t, s) in inputs.iter().zip(&self.spec.inputs) {
            ensure!(
                t.shape() == s.shape.as_slice() && t.dtype() == s.dtype,
                "{}: input '{}' shape/dtype mismatch: got {:?} {:?}, expect {:?} {:?}",
                self.spec.name,
                s.name,
                t.shape(),
                t.dtype(),
                s.shape,
                s.dtype
            );
        }
        Ok(())
    }
}

/// The engine owns the PJRT client and a lazily-populated executable cache.
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Engine {
    /// Build from an artifacts directory (loads manifest, creates CPU client).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Engine { manifest, client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.manifest.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))
            .with_context(|| format!("artifact {name}"))?;
        let executable = Arc::new(Executable { spec, exe });
        self.cache.lock().unwrap().insert(name.to_string(), executable.clone());
        Ok(executable)
    }

    /// Copy a host tensor to a device buffer (persistent across calls).
    ///
    /// TFRT-CPU `BufferFromHostLiteral` copies **asynchronously** and the
    /// crate's shim does not await the transfer — the returned handle keeps
    /// the source literal alive until the buffer is dropped (freeing the
    /// literal early is a use-after-free that crashes inside XLA).
    pub fn to_device(&self, t: &Tensor) -> Result<DeviceTensor> {
        self.literal_to_device(t.to_literal()?)
    }

    /// Move a host literal to a device buffer (keeps the literal alive).
    pub fn literal_to_device(&self, lit: xla::Literal) -> Result<DeviceTensor> {
        let buf = self
            .client
            .buffer_from_host_literal(None, &lit)
            .map_err(|e| anyhow!("to_device: {e:?}"))?;
        Ok(DeviceTensor { buf, _lit: lit })
    }

    /// Copy many host tensors to device buffers.
    pub fn to_device_all(&self, ts: &[Tensor]) -> Result<Vec<DeviceTensor>> {
        ts.iter().map(|t| self.to_device(t)).collect()
    }
}

/// A device buffer pinned together with its source literal (see
/// [`Engine::to_device`] for why the literal must outlive the buffer).
pub struct DeviceTensor {
    pub buf: xla::PjRtBuffer,
    _lit: xla::Literal,
}

impl DeviceTensor {
    pub fn buffer(&self) -> &xla::PjRtBuffer {
        &self.buf
    }
}
