//! Micro-benchmark substrate (no `criterion` offline).
//!
//! Provides warmup + repeated timed runs with mean / p50 / p95 / stddev and a
//! criterion-like console report.  Used by every target in `rust/benches/`
//! (declared with `harness = false`).

use std::time::{Duration, Instant};

/// Result statistics for one benchmark case.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub std_dev: Duration,
    pub throughput: Option<f64>,
}

impl Stats {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

/// Benchmark runner with fixed warmup/measure budgets.
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
    results: Vec<Stats>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            min_iters: 10,
            max_iters: 100_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(100),
            measure: Duration::from_millis(700),
            min_iters: 5,
            max_iters: 10_000,
            results: Vec::new(),
        }
    }

    /// Time `f` repeatedly; `elems_per_iter` (optional) reports throughput.
    pub fn run(&mut self, name: &str, elems_per_iter: Option<f64>, mut f: impl FnMut()) -> Stats {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            f();
        }
        // Measure.
        let mut samples: Vec<Duration> = Vec::new();
        let t0 = Instant::now();
        while (t0.elapsed() < self.measure || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let s = Instant::now();
            f();
            samples.push(s.elapsed());
        }
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let mean = total / n as u32;
        let mean_s = mean.as_secs_f64();
        let var = samples
            .iter()
            .map(|d| {
                let x = d.as_secs_f64() - mean_s;
                x * x
            })
            .sum::<f64>()
            / n as f64;
        let stats = Stats {
            name: name.to_string(),
            iters: n,
            mean,
            p50: samples[n / 2],
            p95: samples[(n * 95 / 100).min(n - 1)],
            std_dev: Duration::from_secs_f64(var.sqrt()),
            throughput: elems_per_iter.map(|e| e / mean_s),
        };
        println!(
            "{:<44} {:>10.3?} mean  {:>10.3?} p50  {:>10.3?} p95  ({} iters){}",
            stats.name,
            stats.mean,
            stats.p50,
            stats.p95,
            stats.iters,
            stats
                .throughput
                .map(|t| format!("  {:.3e} elem/s", t))
                .unwrap_or_default()
        );
        self.results.push(stats.clone());
        stats
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Write results as CSV (name,mean_ns,p50_ns,p95_ns,iters).
    pub fn write_csv(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        let mut out = String::from("name,mean_ns,p50_ns,p95_ns,std_ns,iters\n");
        for s in &self.results {
            out.push_str(&format!(
                "{},{},{},{},{},{}\n",
                s.name,
                s.mean.as_nanos(),
                s.p50.as_nanos(),
                s.p95.as_nanos(),
                s.std_dev.as_nanos(),
                s.iters
            ));
        }
        std::fs::write(path, out)
    }
}

/// One kernel-vs-reference comparison row for `BENCH_kernels.json` — the
/// machine-readable perf trajectory the kernel bench seeds.
#[derive(Debug, Clone)]
pub struct KernelRecord {
    /// Kernel name, e.g. `matmul_f64` / `gar_forward_fused`.
    pub kernel: String,
    /// Shape label, e.g. `512x512x512` or `B=64 n=256 m=256 r=32`.
    pub shape: String,
    pub mean_ns: f64,
    pub gflops: f64,
    /// Kernel-vs-naive-reference speedup (>1 = kernel faster).
    pub speedup_vs_reference: f64,
}

impl KernelRecord {
    /// Build from a kernel [`Stats`] + its reference [`Stats`] at `flops`
    /// floating-point operations per iteration.
    pub fn from_stats(kernel: &Stats, reference: &Stats, shape: &str, flops: f64) -> KernelRecord {
        KernelRecord {
            kernel: kernel.name.clone(),
            shape: shape.to_string(),
            mean_ns: kernel.mean.as_nanos() as f64,
            gflops: flops / kernel.mean_secs() / 1e9,
            speedup_vs_reference: reference.mean_secs() / kernel.mean_secs(),
        }
    }
}

/// Write kernel comparison records as JSON (`BENCH_kernels.json` schema).
pub fn write_kernel_json(
    path: impl AsRef<std::path::Path>,
    records: &[KernelRecord],
) -> std::io::Result<()> {
    use crate::json::{obj, to_string, Value};
    let rows: Vec<Value> = records
        .iter()
        .map(|r| {
            obj(vec![
                ("kernel", Value::Str(r.kernel.clone())),
                ("shape", Value::Str(r.shape.clone())),
                ("mean_ns", Value::Num(r.mean_ns)),
                ("gflops", Value::Num(r.gflops)),
                ("speedup_vs_reference", Value::Num(r.speedup_vs_reference)),
            ])
        })
        .collect();
    std::fs::write(path, to_string(&Value::Arr(rows)))
}

/// `BENCH_QUICK=1` selects the short profile (used by `cargo test` smoke).
pub fn from_env() -> Bench {
    if std::env::var("BENCH_QUICK").is_ok() {
        Bench::quick()
    } else {
        Bench::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            min_iters: 3,
            max_iters: 1000,
            results: vec![],
        };
        let s = b.run("spin", Some(1000.0), || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.iters >= 3);
        assert!(s.mean > Duration::ZERO);
        assert!(s.throughput.unwrap() > 0.0);
    }
}
