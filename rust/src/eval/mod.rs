//! Evaluation harness: figure/table regeneration (`repro figure <id>`,
//! `repro table <id>`) and report/plot utilities.

pub mod figures;
pub mod report;

pub use report::{ascii_chart, Series, Table};
