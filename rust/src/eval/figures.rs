//! Figure/table regeneration: `repro figure <fig2|fig3|fig4|fig5|fig6|fig7a|
//! fig7b|fig8|fig9|fig10>` and `repro table tab1`.
//!
//! Every harness writes a CSV under `results/` with the same series the
//! paper plots, prints an ASCII chart/table, and is indexed in DESIGN.md §4.
//! Absolute numbers differ from the paper (our substrate is the synthetic
//! byte-GPT, not Llama/DINOv3 — DESIGN.md §substitutions); the *shape* of
//! each comparison is the reproduction target.

use anyhow::{bail, Result};

use crate::baselines::controlled;
#[cfg(feature = "pjrt")]
use crate::baselines::transformer;
use crate::cli::Args;
use crate::config::RunConfig;
#[cfg(feature = "pjrt")]
use crate::data::domains::Domain;
#[cfg(feature = "pjrt")]
use crate::data::{Corpus, TokenBatcher};
use crate::data::Digits;
use crate::eval::report::{ascii_chart, write_series_csv, Series, Table};
use crate::flexrank::consolidate::{consolidate, ConsolidateCfg, Target};
use crate::flexrank::dp::{dp_rank_selection, Candidate};
use crate::flexrank::masks::RankProfile;
use crate::flexrank::theory::{self, LinearFactors, Strategy};
use crate::linalg::Mat;
use crate::rng::Rng;
#[cfg(feature = "pjrt")]
use crate::runtime::Engine;
#[cfg(feature = "pjrt")]
use crate::training::{driver, lora, pipeline, CORPUS_BYTES};

pub fn run_cli(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow::anyhow!("usage: repro figure <figN>"))?;
    match which {
        "fig2" => fig2(args),
        "fig3" => fig3(args),
        #[cfg(feature = "pjrt")]
        "fig4" => fig4(args),
        #[cfg(feature = "pjrt")]
        "fig5" => fig5(args),
        #[cfg(feature = "pjrt")]
        "fig6" => fig6(args),
        #[cfg(feature = "pjrt")]
        "fig7a" => fig7a(args),
        #[cfg(feature = "pjrt")]
        "fig7b" => fig7b(args),
        "fig8" => fig8(args),
        "fig9" => fig9(args),
        #[cfg(feature = "pjrt")]
        "fig10" => fig10(args),
        "all-controlled" => {
            fig2(args)?;
            fig3(args)?;
            fig8(args)?;
            fig9(args)
        }
        #[cfg(not(feature = "pjrt"))]
        "fig4" | "fig5" | "fig6" | "fig7a" | "fig7b" | "fig10" => {
            bail!("figure '{which}' runs over the AOT artifacts; rebuild with --features pjrt")
        }
        other => bail!("unknown figure '{other}'"),
    }
}

pub fn run_table_cli(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        #[cfg(feature = "pjrt")]
        Some("tab1") => tab1(args),
        #[cfg(not(feature = "pjrt"))]
        Some("tab1") => bail!("tab1 runs over the AOT artifacts; rebuild with --features pjrt"),
        other => bail!("unknown table {other:?} (expected tab1)"),
    }
}

fn out_path(name: &str) -> std::path::PathBuf {
    crate::results_dir().join(name)
}

// ---------------------------------------------------------------------------
// Fig. 2 — PTS vs ASL vs NSL Pareto fronts on the linear model (Sec. 4)
// ---------------------------------------------------------------------------

fn fig2(args: &Args) -> Result<()> {
    let k = args.usize_or("k", 10)?;
    let steps = args.usize_or("steps", 20_000)?;
    let seed = args.u64_or("seed", 2)?;
    let mut rng = Rng::new(seed);

    // M* with power-law spectrum (decay 1.2, App. D.1).
    let sv: Vec<f64> = (0..k).map(|i| 1.0 / ((i + 1) as f64).powf(1.2)).collect();
    let mstar = Mat::with_singular_values(k, k, &sv, &mut rng);
    // True Pareto front: ‖A_r − M*‖² = Σ_{i>r} σ_i².
    let true_front: Vec<(f64, f64)> = (1..=k)
        .map(|r| (r as f64, sv[r..].iter().map(|s| s * s).sum()))
        .collect();

    let mut series = vec![Series::new("true_front", true_front)];
    for (name, strat, lr) in [
        ("PTS", Strategy::Pts, 0.05),
        ("ASL", Strategy::Asl, 0.02),
        ("NSL", Strategy::Nsl, 0.05),
    ] {
        let mut f = LinearFactors::random(k, k, k, 0.3, &mut rng);
        theory::train(&mut f, &mstar, strat, steps, lr, &mut rng);
        let pts: Vec<(f64, f64)> = (1..=k)
            .map(|r| (r as f64, theory::best_submodel_error(&f, &mstar, r)))
            .collect();
        series.push(Series::new(name, pts));
    }
    // Thm 4.2 lower bound for ASL.
    series.push(Series::new(
        "ASL_thm42_bound",
        (1..=k)
            .map(|r| {
                let base = sv[r..].iter().map(|s| s * s).sum::<f64>();
                (r as f64, base + theory::asl_gap_lower_bound(&sv, r))
            })
            .collect(),
    ));

    write_series_csv(out_path("fig2_nestedness.csv"), &series)?;
    println!("{}", ascii_chart("Fig 2: best-submodel error vs rank", &series, 64, 18));

    // Headline checks (Sec. 4 theorems).
    let nsl = &series[3];
    let worst_nsl_gap = nsl
        .points
        .iter()
        .zip(&series[0].points)
        .map(|((_, got), (_, opt))| got - opt)
        .fold(f64::MIN, f64::max);
    println!("NSL worst gap above true front: {worst_nsl_gap:.2e} (Thm 4.3: → 0)");
    println!("wrote {}", out_path("fig2_nestedness.csv").display());
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 3 — FlexRank recovers the true Pareto front (controlled digits net)
// ---------------------------------------------------------------------------

fn fig3(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 3)?;
    let steps = args.usize_or("steps", 500)?;
    let d = Digits::generate(800, 300, seed);
    let (teacher, tacc) = controlled::train_dense_teacher(&d, 600, seed ^ 1);
    println!("teacher test accuracy: {tacc:.3}");

    let student0 = controlled::decompose_net(&teacher, &d.x, false);
    let fulls = student0.fact_ranks();
    let levels = 8usize;
    let profiles: Vec<RankProfile> = (1..=levels)
        .map(|i| {
            fulls
                .iter()
                .map(|&f| ((f * i) as f64 / levels as f64).ceil().max(1.0) as usize)
                .collect()
        })
        .collect();

    let mut indep_rand = Vec::new();
    let mut indep_svd = Vec::new();
    for (i, prof) in profiles.iter().enumerate() {
        let params = student0.param_count(prof) as f64;
        let (_n1, _a1, l_rand) = controlled::train_independent(
            controlled::random_student(seed ^ (100 + i as u64)),
            &d,
            prof,
            steps,
            seed ^ (200 + i as u64),
        );
        let (_n2, _a2, l_svd) = controlled::train_independent(
            student0.clone(),
            &d,
            prof,
            steps,
            seed ^ (300 + i as u64),
        );
        indep_rand.push((params, l_rand));
        indep_svd.push((params, l_svd));
    }

    // FlexRank: shared weights, nested consolidation on all profiles.
    let mut shared = student0.clone();
    let alphas = vec![1.0 / profiles.len() as f64; profiles.len()];
    let mut rng = Rng::new(seed ^ 0xF3);
    consolidate(
        &mut shared,
        &profiles,
        &alphas,
        &d.x,
        Target::Labels(&d.y),
        &ConsolidateCfg { steps: steps * profiles.len(), lr: 4e-3, batch: 64, log_every: 0 },
        &mut rng,
    );
    let flex: Vec<(f64, f64)> = profiles
        .iter()
        .map(|p| {
            let (loss, _acc) = controlled::eval_net(&shared, &d, p);
            (student0.param_count(p) as f64, loss)
        })
        .collect();

    let series = vec![
        Series::new("independent_from_random", indep_rand),
        Series::new("independent_from_datasvd", indep_svd),
        Series::new("flexrank_shared", flex),
    ];
    write_series_csv(out_path("fig3_pareto_recovery.csv"), &series)?;
    println!("{}", ascii_chart("Fig 3: test loss vs params", &series, 64, 18));
    println!("wrote {}", out_path("fig3_pareto_recovery.csv").display());
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 4 — accuracy/loss vs budget: FlexRank vs SVD / DataSVD / ACIP-like
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
fn fig4(args: &Args) -> Result<()> {
    let rc = run_config(args)?;
    let engine = Engine::new(crate::artifacts_dir())?;
    let out = pipeline::run(&engine, &rc, args.flag("fresh"))?;
    let corpus = Corpus::generate(CORPUS_BYTES, rc.seed);
    let cfg = engine.manifest.config.clone();
    let eval_b = TokenBatcher::new(&corpus.heldout, cfg.batch_eval, cfg.seq_len + 1, cfg.vocab, 1);
    let eval_batches = eval_b.eval_batches(rc.eval_batches);

    // Plain-SVD student (no training) on the same profiles.
    let svd_student = transformer::plain_svd_student(&engine, &out.teacher)?;

    let mut s_svd = Vec::new();
    let mut s_data = Vec::new();
    let mut s_flex = Vec::new();
    let mut a_data = Vec::new();
    let mut a_flex = Vec::new();
    for (beta, prof, before, after) in &out.budget_rows {
        let svd_loss = driver::eval_student(&engine, &svd_student, prof, &eval_batches)?;
        s_svd.push((*beta, svd_loss));
        s_data.push((*beta, *before));
        s_flex.push((*beta, *after));
        a_data.push((*beta, driver::student_accuracy(&engine, &out.student_init, prof, &eval_batches)?));
        a_flex.push((*beta, driver::student_accuracy(&engine, &out.student, prof, &eval_batches)?));
    }

    // ACIP-like: plain-SVD factors frozen + LoRA repair, per serving tier.
    let acip_steps = args.usize_or("acip-steps", rc.consolidate_steps / 4)?;
    let mut s_acip = Vec::new();
    for (i, &tier) in cfg.serve_tiers.iter().enumerate() {
        let (gar, lora_p, _) = lora::adapt_on_text(
            &engine,
            &svd_student,
            i,
            &corpus.train,
            acip_steps,
            rc.seed ^ 0xAC,
        )?;
        let ce = lora::ce_on_text(&engine, i, &gar, &lora_p, &corpus.heldout, rc.eval_batches)?;
        s_acip.push((tier, ce));
    }

    let loss_series = vec![
        Series::new("svd", s_svd),
        Series::new("datasvd", s_data),
        Series::new("flexrank", s_flex),
        Series::new("acip_like", s_acip),
    ];
    let acc_series = vec![Series::new("datasvd", a_data), Series::new("flexrank", a_flex)];
    write_series_csv(out_path("fig4_loss_vs_budget.csv"), &loss_series)?;
    write_series_csv(out_path("fig4_acc_vs_budget.csv"), &acc_series)?;
    println!("{}", ascii_chart("Fig 4 (loss vs budget)", &loss_series, 64, 18));
    println!("{}", ascii_chart("Fig 4 (next-byte accuracy vs budget)", &acc_series, 64, 14));
    println!("wrote {}", out_path("fig4_loss_vs_budget.csv").display());
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 5 — beyond rank-based: pruner-like, layerskip-like, independent
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
fn fig5(args: &Args) -> Result<()> {
    let rc = run_config(args)?;
    let engine = Engine::new(crate::artifacts_dir())?;
    let cfg = engine.manifest.config.clone();
    let out = pipeline::run(&engine, &rc, false)?;
    let corpus = Corpus::generate(CORPUS_BYTES, rc.seed);
    let mut train_b =
        TokenBatcher::new(&corpus.train, cfg.batch_train, cfg.seq_len + 1, cfg.vocab, 91)
        ;
    let eval_b = TokenBatcher::new(&corpus.heldout, cfg.batch_eval, cfg.seq_len + 1, cfg.vocab, 1);
    let eval_batches = eval_b.eval_batches(rc.eval_batches);
    let steps = args.usize_or("steps", rc.consolidate_steps)?;

    // FlexRank curve (already consolidated).
    let flex: Vec<(f64, f64)> =
        out.budget_rows.iter().map(|(b, _p, _x, after)| (*b, *after)).collect();

    // LLM-Pruner-like: magnitude profiles + recovery consolidation.
    let mag_profiles = transformer::magnitude_profiles(&cfg, &out.student_init, &rc.budgets)?;
    let alphas = vec![1.0 / mag_profiles.len() as f64; mag_profiles.len()];
    let mag_run = driver::consolidate(
        &engine, out.student_init.clone(), &out.teacher, &mag_profiles, &alphas,
        &mut train_b, steps, rc.seed ^ 0x51, 0,
    )?;
    let mut pruner = Vec::new();
    for (beta, prof) in rc.budgets.iter().zip(&mag_profiles) {
        pruner.push((*beta, driver::eval_student(&engine, &mag_run.params, prof, &eval_batches)?));
    }

    // LayerSkip-like: depth profiles + self-distillation consolidation.
    let skip_profiles = transformer::layerskip_profiles(&cfg, &rc.budgets);
    let skip_run = driver::consolidate(
        &engine, out.student_init.clone(), &out.teacher, &skip_profiles, &alphas,
        &mut train_b, steps, rc.seed ^ 0x52, 0,
    )?;
    let mut skip = Vec::new();
    for (beta, prof) in rc.budgets.iter().zip(&skip_profiles) {
        skip.push((*beta, driver::eval_student(&engine, &skip_run.params, prof, &eval_batches)?));
    }

    // Independent submodels at matched total budget.
    let flex_profiles: Vec<RankProfile> =
        out.budget_rows.iter().map(|(_b, p, _x, _a)| p.clone()).collect();
    let indep = transformer::independent_submodels(
        &engine, &out.student_init, &out.teacher, &flex_profiles, steps,
        &mut train_b, &eval_batches, rc.seed ^ 0x53,
    )?;
    let indep_pts: Vec<(f64, f64)> =
        rc.budgets.iter().cloned().zip(indep).collect();

    let series = vec![
        Series::new("flexrank", flex),
        Series::new("llm_pruner_like", pruner),
        Series::new("layerskip_like", skip),
        Series::new("independent_matched_budget", indep_pts),
    ];
    write_series_csv(out_path("fig5_families.csv"), &series)?;
    println!("{}", ascii_chart("Fig 5: eval loss vs budget", &series, 64, 18));
    println!("wrote {}", out_path("fig5_families.csv").display());
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 6 — compression-profile heatmaps over submodels
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
fn fig6(args: &Args) -> Result<()> {
    let rc = run_config(args)?;
    let engine = Engine::new(crate::artifacts_dir())?;
    let cfg = engine.manifest.config.clone();
    let out = pipeline::run(&engine, &rc, false)?;

    let budgets = [0.4, 0.6, 0.8, 1.0];
    let profiles = out.chain.select(&budgets, out.full_cost as usize);
    let kinds = ["qkv", "proj", "fc", "fcp"];
    let mut table = Table::new(&["budget", "block", "qkv", "proj", "fc", "fcp"]);
    for (beta, prof) in budgets.iter().zip(&profiles) {
        println!("budget {beta:.1} compression ratio (rank/full, █ = kept):");
        for b in 0..cfg.n_blocks {
            let mut cells = vec![format!("{beta:.1}"), format!("{b}")];
            print!("  block {b}: ");
            for (j, _k) in kinds.iter().enumerate() {
                let ratio = prof[b * 4 + j] as f64 / cfg.rank_full() as f64;
                let bars = (ratio * 8.0).round() as usize;
                print!("{:>5} {:8} ", format!("{:.2}", ratio), "█".repeat(bars));
                cells.push(format!("{ratio:.3}"));
            }
            println!();
            table.row(cells);
        }
    }
    table.write_csv(out_path("fig6_profiles.csv"))?;
    println!("wrote {}", out_path("fig6_profiles.csv").display());
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 7a — calibration sample-count ablation for DataSVD
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
fn fig7a(args: &Args) -> Result<()> {
    let rc = run_config(args)?;
    let engine = Engine::new(crate::artifacts_dir())?;
    let cfg = engine.manifest.config.clone();
    let out = pipeline::run(&engine, &rc, false)?;
    let corpus = Corpus::generate(CORPUS_BYTES, rc.seed);
    let eval_b = TokenBatcher::new(&corpus.heldout, cfg.batch_eval, cfg.seq_len + 1, cfg.vocab, 1);
    let eval_batches = eval_b.eval_batches(rc.eval_batches);

    // Mid-budget uniform profile: the regime where decomposition quality shows.
    let half: RankProfile = vec![cfg.rank_full() / 2; cfg.n_fact_layers()];

    let mut pts = Vec::new();
    for batches in [1usize, 2, 4, 8, 16, 32] {
        let mut calib_b =
            TokenBatcher::new(&corpus.train, cfg.batch_train, cfg.seq_len + 1, cfg.vocab, 0x7A);
        let covs = driver::calibrate(&engine, &out.teacher, &mut calib_b, batches)?;
        let factors =
            crate::training::params::decompose_teacher(&cfg, &out.teacher, Some(&covs))?;
        let student =
            crate::training::params::student_from_factors(&cfg, &out.teacher, &factors)?;
        let loss = driver::eval_student(&engine, &student, &half, &eval_batches)?;
        let samples = batches * cfg.batch_calib * cfg.seq_len;
        pts.push((samples as f64, loss));
        println!("  {samples} samples -> loss {loss:.4}");
    }
    // Plain SVD reference (no data at all).
    let svd_student = transformer::plain_svd_student(&engine, &out.teacher)?;
    let svd_loss = driver::eval_student(&engine, &svd_student, &half, &eval_batches)?;
    let series = vec![
        Series::new("datasvd", pts.clone()),
        Series::new(
            "plain_svd_ref",
            pts.iter().map(|&(x, _)| (x, svd_loss)).collect(),
        ),
    ];
    write_series_csv(out_path("fig7a_calibration.csv"), &series)?;
    println!("{}", ascii_chart("Fig 7a: loss vs calibration samples (50% budget)", &series, 64, 14));
    println!("wrote {}", out_path("fig7a_calibration.csv").display());
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 7b — local (per-layer optimal) vs global (e2e) nestedness
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
fn fig7b(args: &Args) -> Result<()> {
    let rc = run_config(args)?;
    let engine = Engine::new(crate::artifacts_dir())?;
    let out = pipeline::run(&engine, &rc, false)?;

    // Per-layer-optimal decomposition without e2e training (local nestedness)
    // vs end-to-end consolidated (global nestedness).  The paper's
    // "independent layer training" column is the DataSVD solution: each
    // layer's truncation is per-layer optimal under the data norm (Eq. 3),
    // which is exactly what independent layer adaptation converges to for
    // linear layers.
    let local: Vec<(f64, f64)> =
        out.budget_rows.iter().map(|(b, _p, before, _a)| (*b, *before)).collect();
    let global: Vec<(f64, f64)> =
        out.budget_rows.iter().map(|(b, _p, _x, after)| (*b, *after)).collect();
    let series = vec![
        Series::new("per_layer_optimal_no_e2e", local),
        Series::new("e2e_consolidated", global),
    ];
    write_series_csv(out_path("fig7b_local_vs_global.csv"), &series)?;
    println!("{}", ascii_chart("Fig 7b: local vs global nestedness", &series, 64, 14));
    println!("wrote {}", out_path("fig7b_local_vs_global.csv").display());
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 8 — single-budget training lacks elasticity (controlled net)
// ---------------------------------------------------------------------------

fn fig8(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 8)?;
    let steps = args.usize_or("steps", 600)?;
    let d = Digits::generate(800, 300, seed);
    let (teacher, _) = controlled::train_dense_teacher(&d, 600, seed ^ 1);
    let student0 = controlled::decompose_net(&teacher, &d.x, false);
    let fulls = student0.fact_ranks();
    let levels = 5usize;
    let profiles: Vec<RankProfile> = (1..=levels)
        .map(|i| {
            fulls
                .iter()
                .map(|&f| ((f * i) as f64 / levels as f64).ceil().max(1.0) as usize)
                .collect()
        })
        .collect();
    let budgets: Vec<f64> = (1..=levels).map(|i| i as f64 / levels as f64).collect();

    let mut series = Vec::new();
    // Each single-budget model evaluated across every budget.
    for (i, train_prof) in profiles.iter().enumerate() {
        let (net, _acc, _l) = controlled::train_independent(
            student0.clone(),
            &d,
            train_prof,
            steps,
            seed ^ (400 + i as u64),
        );
        let pts: Vec<(f64, f64)> = profiles
            .iter()
            .zip(&budgets)
            .map(|(p, &b)| (b, controlled::eval_net(&net, &d, p).0))
            .collect();
        series.push(Series::new(format!("single_b{:.1}", budgets[i]), pts));
    }
    // FlexRank nested training, matched total budget.
    let mut shared = student0.clone();
    let alphas = vec![1.0 / profiles.len() as f64; profiles.len()];
    let mut rng = Rng::new(seed ^ 0xF8);
    consolidate(
        &mut shared,
        &profiles,
        &alphas,
        &d.x,
        Target::Labels(&d.y),
        &ConsolidateCfg { steps: steps * profiles.len(), lr: 4e-3, batch: 64, log_every: 0 },
        &mut rng,
    );
    let pts: Vec<(f64, f64)> = profiles
        .iter()
        .zip(&budgets)
        .map(|(p, &b)| (b, controlled::eval_net(&shared, &d, p).0))
        .collect();
    series.push(Series::new("flexrank_nested", pts));

    write_series_csv(out_path("fig8_single_budget.csv"), &series)?;
    println!("{}", ascii_chart("Fig 8: loss vs eval budget", &series, 64, 18));
    println!("wrote {}", out_path("fig8_single_budget.csv").display());
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 9 — ranking-preservation analysis of the additive DP probe
// ---------------------------------------------------------------------------

fn fig9(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 9)?;
    let levels = args.usize_or("levels", 10)?;
    let d = Digits::generate(600, 200, seed);
    let (teacher, _) = controlled::train_dense_teacher(&d, 500, seed ^ 1);
    let student = controlled::decompose_net(&teacher, &d.x, false);
    let fulls = student.fact_ranks();
    let n_layers = fulls.len();
    // App. C.3 probing loss: output-matching MSE against the full model's
    // logits on the probe inputs (smooth + label-free, like the paper's
    // joint probing loss).
    let reference = student.forward(&d.x_test, &fulls);
    let probe = |prof: &RankProfile| controlled::eval_probe_mse(&student, &d.x_test, &reference, prof);

    // Per-layer rank grids: `levels` levels each => levels^L profiles.
    let grids: Vec<Vec<usize>> = fulls
        .iter()
        .map(|&f| (1..=levels).map(|i| ((f * i) as f64 / levels as f64).ceil() as usize).collect())
        .collect();

    // Per-layer sensitivities s_l(r): truncate only layer l.  Signed — the
    // analysis needs the probe's full ordering information; clamping ties
    // many candidates at zero and destroys fine-grained ranking (App. C.3's
    // probe is likewise the raw loss delta).
    let full_loss = probe(&fulls);
    let mut sens: Vec<Vec<f64>> = Vec::with_capacity(n_layers);
    for l in 0..n_layers {
        let mut row = Vec::with_capacity(levels);
        for &r in &grids[l] {
            let mut prof = fulls.clone();
            prof[l] = r;
            row.push(probe(&prof) - full_loss);
        }
        sens.push(row);
    }

    // GAR-form cost of a profile (same scale the DP uses).
    let layer_dims: Vec<(usize, usize)> = student
        .layers
        .iter()
        .map(|l| (l.in_dim(), l.out_dim()))
        .collect();
    let gar_cost = |prof: &RankProfile| -> u64 {
        prof.iter()
            .zip(&layer_dims)
            .map(|(&r, &(n, m))| ((n + m - r) * r) as u64)
            .sum()
    };

    // Enumerate all levels^L profiles: A(m) additive probe vs F(m) true loss.
    let total: usize = levels.pow(n_layers as u32);
    let mut a_vals = Vec::with_capacity(total);
    let mut f_vals = Vec::with_capacity(total);
    let mut costs = Vec::with_capacity(total);
    let mut profiles = Vec::with_capacity(total);
    for idx in 0..total {
        let mut rem = idx;
        let mut prof = Vec::with_capacity(n_layers);
        let mut a = 0.0;
        for l in 0..n_layers {
            let li = rem % levels;
            rem /= levels;
            prof.push(grids[l][li]);
            a += sens[l][li];
        }
        let f = probe(&prof);
        costs.push(gar_cost(&prof));
        a_vals.push(a);
        f_vals.push(f);
        profiles.push(prof);
    }

    // Spearman rho between A and F.
    let rho = spearman(&a_vals, &f_vals);
    // Pairwise violation rate on sampled pairs.
    let mut rng = Rng::new(seed ^ 0xF9);
    let mut violations = 0usize;
    let pairs = 100_000usize;
    for _ in 0..pairs {
        let i = rng.below(total);
        let j = rng.below(total);
        if (a_vals[i] - a_vals[j]) * (f_vals[i] - f_vals[j]) < 0.0 {
            violations += 1;
        }
    }
    let nu = violations as f64 / pairs as f64;

    // DP success p + regret over a budget sweep (costs all in GAR scale).
    let full_cost = gar_cost(&fulls);
    let mut candidates: Vec<Vec<Candidate>> = Vec::new();
    for l in 0..n_layers {
        let (n, m) = layer_dims[l];
        let lp = |r: usize| -> u64 { ((n + m - r) * r) as u64 };
        let mut c = vec![];
        for (li, &r) in grids[l].iter().enumerate() {
            c.push(Candidate { saving: lp(fulls[l]) - lp(r), err: sens[l][li], rank: r });
        }
        c.sort_by_key(|x| x.saving);
        candidates.push(c);
    }
    let dp = dp_rank_selection(&candidates, full_cost, 1)?;

    let budgets: Vec<f64> = (1..=50).map(|i| 0.3 + 0.7 * i as f64 / 50.0).collect();
    let mut hits = 0usize;
    let mut regrets = Vec::new();
    for &beta in &budgets {
        let cap = (beta * full_cost as f64) as u64;
        // Brute-force best-F profile within budget.
        let mut best_f = f64::INFINITY;
        let mut best_i = usize::MAX;
        for i in 0..total {
            if costs[i] <= cap && f_vals[i] < best_f {
                best_f = f_vals[i];
                best_i = i;
            }
        }
        if best_i == usize::MAX {
            continue;
        }
        // DP pick: lowest-probe-error feasible state; ties break toward the
        // larger saving (the cheaper model — DP can't distinguish equal-A
        // states, and the cheaper one dominates on the cost axis).
        let pick = dp
            .pareto
            .iter()
            .filter(|(s, _, _)| full_cost - s <= cap)
            .map(|(s, e, p)| (*e, *s, p))
            .min_by(|a, b| a.0.total_cmp(&b.0).then(b.1.cmp(&a.1)));
        if let Some((_, _, prof)) = pick {
            // True probing loss of the DP profile.
            let f_dp = probe(prof);
            // "Hit" = DP found the true exact-budget winner (same profile or
            // same true loss within eval noise).
            let regret = ((f_dp - best_f) / best_f.abs().max(1e-9)).max(0.0);
            if prof == &profiles[best_i] || regret < 1e-3 {
                hits += 1;
            } else {
                regrets.push(regret);
            }
        }
    }
    let p = hits as f64 / budgets.len() as f64;
    regrets.sort_by(|a, b| a.total_cmp(b));

    println!("Fig 9 metrics over {total} submodels:");
    println!("  Spearman rho          = {rho:.4}   (paper: 0.991)");
    println!("  violation rate nu     = {nu:.4}   (paper: 0.037)");
    println!("  DP exact-budget hit p = {p:.4}   (paper: 0.941)");
    if !regrets.is_empty() {
        println!(
            "  regret when missed: mean {:.4}, max {:.4}",
            regrets.iter().sum::<f64>() / regrets.len() as f64,
            regrets.last().unwrap()
        );
    }

    // CSV: ranking scatter + regret CDF.
    let rank_of = |vals: &[f64]| -> Vec<f64> {
        let mut idx: Vec<usize> = (0..vals.len()).collect();
        idx.sort_by(|&a, &b| vals[a].total_cmp(&vals[b]));
        let mut r = vec![0.0; vals.len()];
        for (pos, &i) in idx.iter().enumerate() {
            r[i] = pos as f64 / vals.len() as f64;
        }
        r
    };
    let ra = rank_of(&a_vals);
    let rf = rank_of(&f_vals);
    let stride = (total / 2000).max(1);
    let scatter: Vec<(f64, f64)> =
        (0..total).step_by(stride).map(|i| (ra[i], rf[i])).collect();
    let cdf: Vec<(f64, f64)> = regrets
        .iter()
        .enumerate()
        .map(|(i, &r)| (r, (i + 1) as f64 / regrets.len().max(1) as f64))
        .collect();
    write_series_csv(
        out_path("fig9_ranking.csv"),
        &[Series::new("rank_scatter", scatter), Series::new("regret_cdf", cdf)],
    )?;
    let mut meta = Table::new(&["metric", "value", "paper"]);
    meta.row(vec!["spearman_rho".into(), format!("{rho:.4}"), "0.991".into()]);
    meta.row(vec!["violation_nu".into(), format!("{nu:.4}"), "0.037".into()]);
    meta.row(vec!["dp_hit_p".into(), format!("{p:.4}"), "0.941".into()]);
    meta.write_csv(out_path("fig9_metrics.csv"))?;
    println!("wrote {}", out_path("fig9_metrics.csv").display());
    Ok(())
}

fn spearman(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    let rank = |vals: &[f64]| -> Vec<f64> {
        let mut idx: Vec<usize> = (0..vals.len()).collect();
        idx.sort_by(|&x, &y| vals[x].total_cmp(&vals[y]));
        let mut r = vec![0.0; vals.len()];
        for (pos, &i) in idx.iter().enumerate() {
            r[i] = pos as f64;
        }
        r
    };
    let ra = rank(a);
    let rb = rank(b);
    let d2: f64 = ra.iter().zip(&rb).map(|(x, y)| (x - y) * (x - y)).sum();
    1.0 - 6.0 * d2 / (n * (n * n - 1.0))
}

// ---------------------------------------------------------------------------
// Fig. 10 — GAR vs naive low-rank vs dense forward cost
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
fn fig10(args: &Args) -> Result<()> {
    let engine = Engine::new(crate::artifacts_dir())?;
    let cfg = engine.manifest.config.clone();
    let reps = args.usize_or("reps", 30)?;
    let (bdim, bb) = (cfg.bench_dim, cfg.bench_batch);

    use crate::runtime::Tensor;
    let time_artifact = |name: &str| -> Result<f64> {
        let exe = engine.load(name)?;
        let spec = exe.spec.clone();
        let inputs: Vec<Tensor> = spec
            .inputs
            .iter()
            .map(|s| Tensor::f32(s.shape.clone(), vec![0.01; s.numel()]))
            .collect();
        // Warmup.
        for _ in 0..3 {
            exe.run(&inputs)?;
        }
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            exe.run(&inputs)?;
        }
        Ok(t0.elapsed().as_secs_f64() / reps as f64)
    };

    let dense_t = time_artifact("bench_dense")?;
    let mut low = Vec::new();
    let mut gar = Vec::new();
    let mut low_macs = Vec::new();
    let mut gar_macs = Vec::new();
    let dense_macs = (bdim * bdim) as f64;
    for &r in &cfg.bench_ranks {
        if r > bdim {
            continue;
        }
        let rel = r as f64 / bdim as f64;
        low.push((rel, time_artifact(&format!("bench_lowrank_r{r}"))? / dense_t));
        low_macs.push((rel, (2 * bdim * r) as f64 / dense_macs));
        if r < bdim {
            gar.push((rel, time_artifact(&format!("bench_gar_r{r}"))? / dense_t));
            gar_macs.push((rel, ((2 * bdim - r) * r) as f64 / dense_macs));
        }
        let _ = bb;
    }
    let series = vec![
        Series::new("lowrank_measured", low),
        Series::new("gar_measured", gar),
        Series::new("lowrank_theory", low_macs),
        Series::new("gar_theory", gar_macs),
        Series::new("dense", vec![(0.0, 1.0), (1.0, 1.0)]),
    ];
    write_series_csv(out_path("fig10_gar.csv"), &series)?;
    println!(
        "{}",
        ascii_chart("Fig 10: forward cost relative to dense vs active rank", &series, 64, 18)
    );
    println!("wrote {}", out_path("fig10_gar.csv").display());
    Ok(())
}

// ---------------------------------------------------------------------------
// Tab. 1 — LoRA post-adaptation across elastic sizes
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
fn tab1(args: &Args) -> Result<()> {
    let rc = run_config(args)?;
    let engine = Engine::new(crate::artifacts_dir())?;
    let cfg = engine.manifest.config.clone();
    let out = pipeline::run(&engine, &rc, false)?;
    let steps = args.usize_or("lora-steps", rc.consolidate_steps / 2)?;

    let mut table = Table::new(&["relative_size", "math_acc", "code_acc"]);
    // Base = unadapted full tier (LoRA at zero steps => B=0 adapters inert).
    let last = cfg.serve_tiers.len() - 1;
    let mut base_cells = vec!["base(no-lora)".to_string()];
    for domain in [Domain::Math, Domain::Code] {
        let (_, acc) = lora::adapt_tier(&engine, &out.student, last, domain, 0, rc.seed ^ 0xB0)?;
        base_cells.push(format!("{acc:.3}"));
    }
    table.row(base_cells);

    for (i, &tier) in cfg.serve_tiers.iter().enumerate().rev() {
        let mut cells = vec![format!("{tier:.2}x")];
        for domain in [Domain::Math, Domain::Code] {
            let (_, acc) =
                lora::adapt_tier(&engine, &out.student, i, domain, steps, rc.seed ^ (0xB1 + i as u64))?;
            cells.push(format!("{acc:.3}"));
        }
        table.row(cells);
    }
    table.print();
    table.write_csv(out_path("tab1_lora.csv"))?;
    println!("wrote {}", out_path("tab1_lora.csv").display());
    Ok(())
}

fn run_config(args: &Args) -> Result<RunConfig> {
    if args.flag("smoke") {
        RunConfig::smoke().with_args(args)
    } else {
        RunConfig::default().with_args(args)
    }
}
