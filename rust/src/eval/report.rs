//! Result reporting: CSV writers + ASCII line charts for figure series.

use std::fmt::Write as _;
use std::path::Path;

/// One named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>, points: Vec<(f64, f64)>) -> Self {
        Series { name: name.into(), points }
    }
}

/// Write series as long-form CSV: series,x,y.
pub fn write_series_csv(path: impl AsRef<Path>, series: &[Series]) -> std::io::Result<()> {
    let mut out = String::from("series,x,y\n");
    for s in series {
        for (x, y) in &s.points {
            let _ = writeln!(out, "{},{x},{y}", s.name);
        }
    }
    std::fs::write(path, out)
}

/// Minimal ASCII line chart (markers per series) for terminal inspection.
pub fn ascii_chart(title: &str, series: &[Series], width: usize, height: usize) -> String {
    let mut all: Vec<(f64, f64)> = series.iter().flat_map(|s| s.points.clone()).collect();
    all.retain(|(x, y)| x.is_finite() && y.is_finite());
    if all.is_empty() {
        return format!("{title}: (no data)\n");
    }
    let (xmin, xmax) = all
        .iter()
        .fold((f64::MAX, f64::MIN), |(lo, hi), &(x, _)| (lo.min(x), hi.max(x)));
    let (ymin, ymax) = all
        .iter()
        .fold((f64::MAX, f64::MIN), |(lo, hi), &(_, y)| (lo.min(y), hi.max(y)));
    let xspan = (xmax - xmin).max(1e-12);
    let yspan = (ymax - ymin).max(1e-12);

    let markers = ['*', 'o', '+', 'x', '#', '@', '%', '&'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let m = markers[si % markers.len()];
        for &(x, y) in &s.points {
            if !x.is_finite() || !y.is_finite() {
                continue;
            }
            let cx = (((x - xmin) / xspan) * (width - 1) as f64).round() as usize;
            let cy = (((y - ymin) / yspan) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx.min(width - 1)] = m;
        }
    }
    let mut out = String::new();
    let _ = writeln!(out, "{title}");
    let _ = writeln!(out, "  y: [{ymin:.4}, {ymax:.4}]  x: [{xmin:.3}, {xmax:.3}]");
    for row in grid {
        let _ = writeln!(out, "  |{}", row.iter().collect::<String>());
    }
    let _ = writeln!(out, "  +{}", "-".repeat(width));
    for (si, s) in series.iter().enumerate() {
        let _ = writeln!(out, "  {} = {}", markers[si % markers.len()], s.name);
    }
    out
}

/// Simple fixed-width table printer + CSV writer.
#[derive(Debug, Clone)]
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", line(&self.headers));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        std::fs::write(path, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chart_renders_extremes() {
        let s = vec![Series::new("a", vec![(0.0, 0.0), (1.0, 1.0)])];
        let c = ascii_chart("t", &s, 10, 5);
        assert!(c.contains('*'));
        assert!(c.contains("a"));
    }

    #[test]
    fn chart_handles_empty() {
        let c = ascii_chart("t", &[], 10, 5);
        assert!(c.contains("no data"));
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let dir = std::env::temp_dir().join("flexrank_table_test.csv");
        t.write_csv(&dir).unwrap();
        let txt = std::fs::read_to_string(&dir).unwrap();
        assert_eq!(txt, "a,b\n1,2\n");
    }
}
