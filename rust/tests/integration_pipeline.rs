#![cfg(feature = "pjrt")]

//! Integration: the full FlexRank pipeline in smoke mode (few steps each
//! stage) — proves all stages compose: pretrain → calibrate → DataSVD →
//! probe → DP → consolidate → eval.  Requires `make artifacts`.

use flexrank::config::RunConfig;
use flexrank::runtime::Engine;
use flexrank::training::pipeline;

#[test]
#[cfg_attr(debug_assertions, ignore = "slow under debug; run via `cargo test --release` (make test)")]
fn smoke_pipeline_composes_all_stages() {
    // Isolated results dir so we never clobber a real run's checkpoints.
    let dir = std::env::temp_dir().join(format!("flexrank_it_{}", std::process::id()));
    std::env::set_var("FLEXRANK_RESULTS", &dir);
    let _ = std::fs::create_dir_all(&dir);

    let engine = Engine::new(flexrank::artifacts_dir()).expect("run `make artifacts` first");
    let mut rc = RunConfig::smoke();
    rc.budgets = vec![0.25, 0.5, 1.0];
    rc.alphas = vec![1.0 / 3.0; 3];

    let out = pipeline::run(&engine, &rc, true).expect("pipeline failed");

    // Chain invariants.
    assert!(out.chain.validate(), "DP chain must be nested + cost-ascending");
    assert!(!out.chain.profiles.is_empty());
    assert!(out.full_cost > 0);

    // Budget rows: ascending budgets, finite losses, profiles nested.
    assert_eq!(out.budget_rows.len(), 3);
    for ((b, prof, before, after), expect_b) in out.budget_rows.iter().zip([0.25, 0.5, 1.0]) {
        assert_eq!(*b, expect_b);
        assert!(before.is_finite() && after.is_finite());
        assert_eq!(prof.len(), engine.manifest.config.n_fact_layers());
    }
    for w in out.budget_rows.windows(2) {
        assert!(
            flexrank::flexrank::masks::is_nested(&w[0].1, &w[1].1),
            "budget profiles must be nested"
        );
    }

    // Pretraining made progress even in 3 steps (loss must drop from ~ln V).
    assert!(out.pretrain_losses.first().unwrap() > out.pretrain_losses.last().unwrap());

    std::env::remove_var("FLEXRANK_RESULTS");
    let _ = std::fs::remove_dir_all(&dir);
}
