//! Listener front-end e2e: real sockets in, bit-identical tokens out.
//!
//! The load-bearing test drives a multi-tenant trace through the framed
//! protocol over loopback and asserts the responses equal a sequential
//! per-request replay on a same-seed registry — `decode_equivalence`
//! pins continuous batching ≡ sequential replay, so the socket path must
//! reproduce it bit for bit.  Around it: the zero-alloc ingest fingerprint
//! stays flat, a saturated admission queue sheds explicitly, adversarial
//! byte streams kill their own connection loudly but never the listener,
//! the HTTP fallback round-trips, and shutdown drains without losing or
//! duplicating a single admitted request.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use flexrank::config::load_model_config;
use flexrank::coordinator::{
    ListenCfg, ListenReport, Listener, Policy, PolicyKind, ServeCfg, ShutdownHandle,
    SubmodelRegistry,
};
use flexrank::data::trace::wire::{self, Status};
use flexrank::data::trace::Slo;
use flexrank::data::{Corpus, Request, TraceCfg, TraceGen};
use flexrank::runtime::{ModelConfig, ServingBackend};
use flexrank::training::params::{
    decompose_teacher, random_teacher, student_from_factors, ParamSet,
};

fn tiny_student(seed: u64) -> (ModelConfig, ParamSet) {
    let cfg = load_model_config("tiny").unwrap();
    let teacher = random_teacher(&cfg, seed);
    let factors = decompose_teacher(&cfg, &teacher, None).unwrap();
    let student = student_from_factors(&cfg, &teacher, &factors).unwrap();
    (cfg, student)
}

struct TestServer {
    addr: SocketAddr,
    handle: ShutdownHandle,
    join: std::thread::JoinHandle<anyhow::Result<ListenReport>>,
}

impl TestServer {
    /// Graceful drain, then the final report.
    fn stop(self) -> ListenReport {
        self.handle.shutdown();
        self.join.join().expect("server thread").expect("listener run")
    }
}

/// Bind an ephemeral port and run a listener over a fresh same-seed tiny
/// registry on its own thread (the serving loop owns the backend).
fn spawn_listener(seed: u64, lcfg: ListenCfg) -> TestServer {
    let listener = Listener::bind("127.0.0.1:0", lcfg).expect("bind ephemeral port");
    let addr = listener.local_addr().unwrap();
    let handle = listener.shutdown_handle();
    let join = std::thread::spawn(move || -> anyhow::Result<ListenReport> {
        let (cfg, student) = tiny_student(seed);
        let mut reg = SubmodelRegistry::load_native(&cfg, &student, None)?;
        listener.run(&mut reg)
    });
    TestServer { addr, handle, join }
}

fn connect(addr: SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).expect("connect");
    s.set_nodelay(true).ok();
    // Generous cap so a wedged server fails the test instead of hanging it.
    s.set_read_timeout(Some(Duration::from_secs(30))).ok();
    s
}

/// Read response frames until `want` arrived or the server closed.
fn read_replies(stream: &mut TcpStream, want: usize) -> Vec<(u64, Status, Vec<i32>)> {
    let mut buf = Vec::with_capacity(wire::MAX_PAYLOAD);
    let mut out = Vec::new();
    while out.len() < want {
        match wire::read_frame(stream, &mut buf, wire::MAX_PAYLOAD) {
            Ok(Some(magic)) => {
                assert_eq!(magic, wire::RESP_MAGIC, "server sent a non-response frame");
                out.push(wire::decode_response(&buf).expect("response frame decodes"));
            }
            Ok(None) => break,
            Err(e) => panic!("reading replies: {e}"),
        }
    }
    out
}

fn read_to_eof(s: &mut TcpStream) -> Vec<u8> {
    let mut out = Vec::new();
    let mut chunk = [0u8; 1024];
    loop {
        match s.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => out.extend_from_slice(&chunk[..n]),
            Err(e) => panic!("reading to EOF: {e}"),
        }
    }
    out
}

fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best as i32
}

/// Greedy-decode one request in isolation on the oracle registry — the
/// reference the socket path must reproduce exactly.  Tier choice mirrors
/// the listener's static-policy routing (depth-independent).
fn sequential_oracle(cfg: &ModelConfig, reg: &mut SubmodelRegistry, req: &Request) -> Vec<i32> {
    if req.gen_len == 0 {
        return Vec::new();
    }
    let tier = Policy::new(PolicyKind::Static, reg.n_tiers()).select(req, 0);
    let vocab = cfg.vocab;
    let slot = reg.acquire_slot(req.total_tokens()).expect("oracle slot");
    let mut out = Vec::new();
    let mut last = {
        let logits = reg.prefill(tier, slot, &req.tokens).unwrap();
        argmax(&logits[(req.tokens.len() - 1) * vocab..req.tokens.len() * vocab])
    };
    out.push(last);
    for _ in 1..req.gen_len {
        let logits = reg.decode_step(tier, &[slot], &[last]).unwrap();
        last = argmax(&logits[..vocab]);
        out.push(last);
    }
    reg.release_slot(slot);
    out
}

fn lcfg(queue_cap: usize, conn_pipeline: usize) -> ListenCfg {
    ListenCfg {
        serve: ServeCfg { policy: PolicyKind::Static, max_wait_ms: 2.0, replay_speed: 1.0, ..Default::default() },
        max_connections: 8,
        queue_cap,
        conn_pipeline,
    }
}

const SEED: u64 = 321;

/// Acceptance: multi-tenant trace over real sockets ≡ in-process replay,
/// ingest fingerprint flat, clean drain with every request answered once.
#[test]
fn socket_responses_match_in_process_replay() {
    let server = spawn_listener(SEED, lcfg(64, 8));

    let (cfg, student) = tiny_student(SEED);
    let mut oracle_reg = SubmodelRegistry::load_native(&cfg, &student, None).unwrap();

    let corpus = Corpus::generate(20_000, 5);
    let trace = TraceGen::new(
        TraceCfg {
            n_requests: 24,
            seq_len: cfg.seq_len,
            vocab: cfg.vocab,
            seed: 9,
            prompt_len_min: (cfg.seq_len / 8).max(1),
            prompt_len_max: cfg.seq_len / 2,
            gen_len_min: 1,
            gen_len_max: (cfg.seq_len / 4).max(1),
            ..Default::default()
        },
        &corpus.heldout,
    )
    .expect("trace cfg must validate")
    .generate();

    let want: HashMap<u64, Vec<i32>> = trace
        .iter()
        .map(|r| (r.id, sequential_oracle(&cfg, &mut oracle_reg, r)))
        .collect();

    // Three tenants, each pipelining its share over one connection.
    let clients: Vec<_> = (0u64..3)
        .map(|tenant| {
            let chunk: Vec<Request> =
                trace.iter().filter(|r| r.id % 3 == tenant).cloned().collect();
            let addr = server.addr;
            std::thread::spawn(move || {
                let mut stream = connect(addr);
                let mut out = Vec::new();
                for r in &chunk {
                    wire::encode_request(&mut out, r);
                }
                stream.write_all(&out).unwrap();
                read_replies(&mut stream, chunk.len())
            })
        })
        .collect();

    let mut got: HashMap<u64, (Status, Vec<i32>)> = HashMap::new();
    for c in clients {
        for (id, status, tokens) in c.join().expect("tenant thread") {
            assert!(
                got.insert(id, (status, tokens)).is_none(),
                "duplicate reply for request {id}"
            );
        }
    }
    let report = server.stop();

    assert_eq!(got.len(), trace.len(), "every request answered exactly once");
    for r in &trace {
        let (status, tokens) = &got[&r.id];
        assert_eq!(*status, Status::Ok, "request {} was not served", r.id);
        assert_eq!(
            tokens, &want[&r.id],
            "request {}: socket tokens diverge from the in-process replay",
            r.id
        );
    }
    assert_eq!(report.requests_done, trace.len());
    assert_eq!(report.shed, 0, "uncontended run must not shed");
    assert_eq!(report.conn_errors, 0);
    assert_eq!(
        report.ingest_fingerprint_drift, 0,
        "zero-alloc ingest invariant broke: a request-slot buffer changed identity"
    );
}

/// Acceptance: a burst past `queue_cap` sheds explicitly — every request
/// still answered (Ok or Shed), nothing queues without bound, nothing leaks.
#[test]
fn saturated_queue_sheds_instead_of_queueing_unboundedly() {
    let mut cfg = lcfg(2, 32);
    cfg.serve.max_wait_ms = 1.0;
    let server = spawn_listener(77, cfg);
    let mcfg = load_model_config("tiny").unwrap();

    let n = 32u64;
    let gen_len = mcfg.seq_len - 4; // longest legal decode: slow on purpose
    let mut stream = connect(server.addr);
    let mut out = Vec::new();
    for id in 1..=n {
        let req = Request {
            id,
            arrival_s: 0.0,
            slo: Slo::Quality,
            tokens: vec![1, 2, 3, 4],
            gen_len,
            budget: None,
        };
        wire::encode_request(&mut out, &req);
    }
    stream.write_all(&out).unwrap();
    let replies = read_replies(&mut stream, n as usize);
    let report = server.stop();

    assert_eq!(replies.len(), n as usize, "every pipelined request answered");
    let ok = replies.iter().filter(|(_, s, _)| *s == Status::Ok).count();
    let shed = replies.iter().filter(|(_, s, _)| *s == Status::Shed).count();
    assert_eq!(ok + shed, n as usize, "only Ok/Shed expected under saturation");
    assert!(shed >= 1, "a 2-deep admission bound must shed some of a 32-deep burst");
    assert!(ok >= 1, "the admitted head of the burst must still serve");
    for (id, s, tokens) in &replies {
        match s {
            Status::Ok => assert_eq!(tokens.len(), gen_len, "request {id} short-served"),
            _ => assert!(tokens.is_empty(), "shed reply for {id} must carry no tokens"),
        }
    }
    // The report agrees with what the client saw — no admitted request
    // was dropped on the floor, no shed was double-counted.
    assert_eq!(report.shed, shed);
    assert_eq!(report.requests_done, ok);
    assert_eq!(report.ingest_fingerprint_drift, 0);
}

/// Satellite: adversarial byte streams — truncated frame, oversized length
/// prefix, garbage bytes, mid-frame disconnect, malformed payload, and an
/// in-contract violation pipelined between good requests.  Each kills (at
/// most) its own connection loudly; the accept loop and the serving loop
/// keep going, and no batcher entry leaks.
#[test]
fn adversarial_streams_fail_loudly_without_killing_the_listener() {
    let server = spawn_listener(123, lcfg(8, 4));

    // (a) Header promises 80 payload bytes (legal), 10 arrive, disconnect.
    {
        let mut s = connect(server.addr);
        let mut out = vec![wire::REQ_MAGIC, wire::VERSION];
        out.extend_from_slice(&80u32.to_le_bytes());
        out.extend_from_slice(&[0u8; 10]);
        s.write_all(&out).unwrap();
    }
    // (b) Oversized length prefix: connection must close with no reply.
    {
        let mut s = connect(server.addr);
        let mut out = vec![wire::REQ_MAGIC, wire::VERSION];
        out.extend_from_slice(&u32::MAX.to_le_bytes());
        s.write_all(&out).unwrap();
        assert!(read_to_eof(&mut s).is_empty(), "no frame for a framing attack");
    }
    // (c) Garbage bytes (neither framed magic nor HTTP), then disconnect.
    {
        let mut s = connect(server.addr);
        s.write_all(&[0xAAu8; 32]).unwrap();
    }
    // (d) Mid-frame disconnect: only half the header ever arrives.
    {
        let mut s = connect(server.addr);
        s.write_all(&[wire::REQ_MAGIC, wire::VERSION, 7]).unwrap();
    }
    // (e) Well-framed but malformed payload (bad SLO code): the stream is
    // poisoned, so the server answers Error and drops the connection.
    {
        let mut s = connect(server.addr);
        let good = Request {
            id: 900,
            arrival_s: 0.0,
            slo: Slo::Standard,
            tokens: vec![1, 2],
            gen_len: 1,
            budget: None,
        };
        let mut out = Vec::new();
        wire::encode_request(&mut out, &good);
        out[wire::HEADER_LEN + 17] = 9; // stomp the slo byte
        s.write_all(&out).unwrap();
        let replies = read_replies(&mut s, 1);
        assert_eq!(replies.len(), 1);
        assert_eq!(replies[0].1, Status::Error);
        assert!(read_to_eof(&mut s).is_empty(), "poisoned stream must close");
    }
    // (f) A contract violation (empty prompt) pipelined between two good
    // requests: per-request Error, the connection and its neighbors live.
    {
        let mut s = connect(server.addr);
        let mk = |id: u64, tokens: Vec<i32>| Request {
            id,
            arrival_s: 0.0,
            slo: Slo::Interactive,
            tokens,
            gen_len: 2,
            budget: None,
        };
        let mut out = Vec::new();
        wire::encode_request(&mut out, &mk(1, vec![1, 2, 3]));
        wire::encode_request(&mut out, &mk(2, vec![])); // empty prompt
        wire::encode_request(&mut out, &mk(3, vec![4, 5]));
        s.write_all(&out).unwrap();
        let by_id: HashMap<u64, Status> =
            read_replies(&mut s, 3).into_iter().map(|(id, st, _)| (id, st)).collect();
        assert_eq!(by_id[&1], Status::Ok);
        assert_eq!(by_id[&2], Status::Error, "contract violation answers Error");
        assert_eq!(by_id[&3], Status::Ok, "the connection survives a bad neighbor");
    }
    // The listener survived all of it: a fresh connection still serves.
    {
        let mut s = connect(server.addr);
        let req = Request {
            id: 999,
            arrival_s: 0.0,
            slo: Slo::Quality,
            tokens: vec![7, 8, 9],
            gen_len: 3,
            budget: Some(1.0),
        };
        let mut out = Vec::new();
        wire::encode_request(&mut out, &req);
        s.write_all(&out).unwrap();
        let replies = read_replies(&mut s, 1);
        assert_eq!(replies[0].0, 999);
        assert_eq!(replies[0].1, Status::Ok);
        assert_eq!(replies[0].2.len(), 3);
    }
    let report = server.stop();
    // (a)–(d) and (e) each errored their own connection, loudly.
    assert_eq!(report.conn_errors, 5, "each adversarial stream counted once");
    // No batcher entry leaked: exactly the three good requests completed.
    assert_eq!(report.requests_done, 3);
    assert_eq!(report.shed, 0);
}

/// Satellite: the HTTP/1.1 POST fallback serves the same tokens as the
/// framed path (and the in-process oracle), and rejects bad bodies with a
/// 400 instead of a hung or poisoned connection.
#[test]
fn http_fallback_round_trips_and_rejects_bad_bodies() {
    let server = spawn_listener(SEED, lcfg(8, 4));
    let (cfg, student) = tiny_student(SEED);
    let mut oracle_reg = SubmodelRegistry::load_native(&cfg, &student, None).unwrap();
    let req = Request {
        id: 5,
        arrival_s: 0.0,
        slo: Slo::Standard, // the JSON default when 'slo' is omitted
        tokens: vec![1, 2, 3],
        gen_len: 4,
        budget: None,
    };
    let want = sequential_oracle(&cfg, &mut oracle_reg, &req);

    let body = r#"{"id": 5, "tokens": [1, 2, 3], "gen_len": 4}"#;
    let mut s = connect(server.addr);
    let msg = format!(
        "POST / HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(msg.as_bytes()).unwrap();
    let text = String::from_utf8(read_to_eof(&mut s)).unwrap();
    assert!(text.starts_with("HTTP/1.1 200"), "unexpected response: {text}");
    let json_body = &text[text.find("\r\n\r\n").unwrap() + 4..];
    let parsed = flexrank::json::parse(json_body).unwrap();
    assert_eq!(parsed.get("id").unwrap().as_f64().unwrap(), 5.0);
    assert_eq!(parsed.get("status").unwrap().as_str().unwrap(), "ok");
    let tokens: Vec<i32> = parsed
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_i64().unwrap() as i32)
        .collect();
    assert_eq!(tokens, want, "HTTP tokens diverge from the in-process replay");

    // Missing 'tokens' → 400 with a JSON error, not a hang.
    let bad = r#"{"id": 1}"#;
    let mut s = connect(server.addr);
    let msg = format!(
        "POST / HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{bad}",
        bad.len()
    );
    s.write_all(msg.as_bytes()).unwrap();
    let text = String::from_utf8(read_to_eof(&mut s)).unwrap();
    assert!(text.starts_with("HTTP/1.1 400"), "unexpected response: {text}");

    // Non-POST → 400.
    let mut s = connect(server.addr);
    s.write_all(b"GET / HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    let text = String::from_utf8(read_to_eof(&mut s)).unwrap();
    assert!(text.starts_with("HTTP/1.1 400"), "unexpected response: {text}");

    let report = server.stop();
    assert_eq!(report.requests_done, 1);
    // The two rejected HTTP requests errored loudly without serving.
    assert_eq!(report.conn_errors, 2);
}

/// Acceptance: shutdown mid-flight drains — every admitted request
/// completes (oldest-head-first admission keeps running), late reads shed,
/// and the client sees exactly one reply per request: none lost, none
/// duplicated.
#[test]
fn shutdown_drains_in_flight_requests_without_loss() {
    let server = spawn_listener(55, lcfg(16, 16));
    let mcfg = load_model_config("tiny").unwrap();

    let n = 12u64;
    let gen_len = mcfg.seq_len - 2;
    let mut stream = connect(server.addr);
    let mut out = Vec::new();
    for id in 1..=n {
        let req = Request {
            id,
            arrival_s: 0.0,
            slo: Slo::ALL[id as usize % Slo::ALL.len()],
            tokens: vec![1, 2],
            gen_len,
            budget: None,
        };
        wire::encode_request(&mut out, &req);
    }
    stream.write_all(&out).unwrap();
    // Let some requests admit, then pull the plug mid-flight.
    std::thread::sleep(Duration::from_millis(2));
    server.handle.shutdown();

    // Read until the drain closes the connection.
    let replies = read_replies(&mut stream, n as usize);
    let report = server.join.join().expect("server thread").expect("listener run");

    let mut seen = HashMap::new();
    for (id, status, _) in &replies {
        assert!(seen.insert(*id, *status).is_none(), "request {id} answered twice");
        assert!(
            matches!(status, Status::Ok | Status::Shed),
            "request {id}: drain must answer Ok or Shed, got {status:?}"
        );
    }
    assert_eq!(seen.len(), n as usize, "drain lost requests: {seen:?}");
    let ok = replies.iter().filter(|(_, s, _)| *s == Status::Ok).count();
    assert_eq!(
        report.requests_done, ok,
        "every admitted request must complete during the drain"
    );
    assert_eq!(report.shed, n as usize - ok);
    assert_eq!(report.ingest_fingerprint_drift, 0);
}
