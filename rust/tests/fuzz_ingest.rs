//! Fuzz-style smoke + allocation proof for the ingest path.
//!
//! Two invariants guard the listener's hot path:
//!
//! 1. **Panic-free**: the frame reader, the binary request decoder, and the
//!    JSON pull parser must return `Err` — never panic, never overflow the
//!    stack — on arbitrarily mutated input (seeded, deterministic).
//! 2. **Zero-alloc**: decoding a valid request into a reused
//!    [`wire::RequestSlot`] performs zero heap allocations after warmup.
//!    The listener pins this with a buffer-identity fingerprint; here the
//!    proof is counted at the allocator itself, via a thread-local counter
//!    in a custom `#[global_allocator]` (thread-local so the harness's
//!    other test threads can't perturb the count).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use flexrank::data::trace::wire;
use flexrank::data::trace::{Request, Slo};
use flexrank::json::pull::{Event, PullParser};
use flexrank::rng::Rng;

struct CountingAlloc;

std::thread_local! {
    // const-init + no destructor: the TLS access compiles to a plain
    // thread-local load, safe inside the allocator.
    static TL_ALLOCS: Cell<usize> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        TL_ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        TL_ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Run `f`, returning its result and how many heap allocations (including
/// reallocations) this thread performed inside it.
fn counted<R>(f: impl FnOnce() -> R) -> (R, usize) {
    let before = TL_ALLOCS.with(|c| c.get());
    let r = f();
    let after = TL_ALLOCS.with(|c| c.get());
    (r, after - before)
}

fn sample_request(rng: &mut Rng, id: u64, max_tokens: usize) -> Request {
    Request {
        id,
        arrival_s: 0.0,
        slo: Slo::ALL[rng.below(3)],
        tokens: (0..1 + rng.below(max_tokens)).map(|_| rng.below(64) as i32).collect(),
        gen_len: rng.below(8),
        budget: if rng.f64() < 0.5 { Some(rng.f64().max(0.01)) } else { None },
    }
}

/// The binary ingest path — frame read + request decode through a reused
/// slot — allocates exactly zero times per request after warmup.
#[test]
fn framed_ingest_decodes_with_zero_allocations() {
    let seq = 64usize;
    let mut rng = Rng::new(0xF7);
    // Pipelined stream of valid frames (allocation here is fine — this is
    // the client side).
    let mut stream: Vec<u8> = Vec::new();
    let n = 200u64;
    for id in 1..=n {
        wire::encode_request(&mut stream, &sample_request(&mut rng, id, seq));
    }

    let max_payload = wire::REQ_FIXED + 4 * seq;
    let mut buf: Vec<u8> = Vec::with_capacity(max_payload);
    let mut slot = wire::RequestSlot::with_capacity(seq);

    // Warmup: first decode may fault in lazily-initialized state.
    let mut r: &[u8] = &stream;
    assert_eq!(wire::read_frame(&mut r, &mut buf, max_payload).unwrap(), Some(wire::REQ_MAGIC));
    wire::decode_request(&buf, seq, &mut slot).unwrap();

    let fp = slot.fingerprint();
    let (sum, allocs) = counted(|| {
        let mut sum = 0u64;
        loop {
            match wire::read_frame(&mut r, &mut buf, max_payload) {
                Ok(Some(_)) => {
                    wire::decode_request(&buf, seq, &mut slot).expect("valid frame");
                    sum = sum.wrapping_add(slot.id).wrapping_add(slot.tokens.len() as u64);
                }
                Ok(None) => break sum,
                Err(e) => panic!("valid stream failed: {e}"),
            }
        }
    });
    assert!(sum > 0);
    assert_eq!(allocs, 0, "framed ingest allocated {allocs} times for {} frames", n - 1);
    assert_eq!(slot.fingerprint(), fp, "slot buffer changed identity");
}

/// The HTTP-fallback pull-parse path is also allocation-free per request —
/// the tree parser (a heap node per JSON value) stays banned from ingest.
#[test]
fn json_pull_ingest_decodes_with_zero_allocations() {
    let body = br#"{"id": 42, "unknown": {"nested": [1, "x", null]}, "tokens":
                    [1, 2, 3, 4, 5, 6, 7, 8], "gen_len": 5, "budget": 0.75,
                    "slo": "interactive"}"#;
    let mut slot = wire::RequestSlot::with_capacity(16);
    wire::decode_request_json(body, 16, &mut slot).unwrap(); // warmup
    let fp = slot.fingerprint();

    let (_, allocs) = counted(|| {
        for _ in 0..100 {
            wire::decode_request_json(body, 16, &mut slot).expect("valid body");
        }
    });
    assert_eq!(slot.id, 42);
    assert_eq!(slot.tokens.len(), 8);
    assert_eq!(allocs, 0, "pull-parse ingest allocated {allocs} times over 100 bodies");
    assert_eq!(slot.fingerprint(), fp, "slot buffer changed identity");
}

/// Seeded byte mutations of valid frames: the frame reader and request
/// decoder must answer every corruption with `Err`, never a panic, and the
/// reused slot must survive to decode the next valid frame.
#[test]
fn mutated_frames_never_panic_the_decoders() {
    let seq = 64usize;
    let mut rng = Rng::new(0x5eed);
    let mut slot = wire::RequestSlot::with_capacity(seq);
    let mut buf: Vec<u8> = Vec::with_capacity(wire::MAX_PAYLOAD);
    for round in 0..2000u64 {
        let mut frame = Vec::new();
        wire::encode_request(&mut frame, &sample_request(&mut rng, round, seq));
        if rng.below(4) == 0 {
            // Truncation (mid-header, mid-payload, or empty).
            let cut = rng.below(frame.len() + 1);
            frame.truncate(cut);
        } else {
            // 1..8 random byte stomps (length prefix, magic, counts, ...).
            for _ in 0..1 + rng.below(8) {
                let i = rng.below(frame.len());
                frame[i] = rng.below(256) as u8;
            }
        }
        let mut r: &[u8] = &frame;
        // Any Ok/Err outcome is acceptable; panics and hangs are not.
        if let Ok(Some(magic)) = wire::read_frame(&mut r, &mut buf, wire::MAX_PAYLOAD) {
            if magic == wire::REQ_MAGIC {
                let _ = wire::decode_request(&buf, seq, &mut slot);
            } else {
                let _ = wire::decode_response(&buf);
            }
        }
        // The slot is still serviceable after arbitrary garbage.
        let mut good = Vec::new();
        wire::encode_request(&mut good, &sample_request(&mut rng, round, seq));
        wire::decode_request(&good[wire::HEADER_LEN..], seq, &mut slot)
            .expect("slot must survive mutated input");
    }
}

/// Seeded mutations of a JSON body: the pull parser and the visitor decoder
/// return `Err` on garbage — no panics, no unbounded loops, and (via the
/// bitstack depth cap) no stack overflow on nesting bombs.
#[test]
fn mutated_json_never_panics_the_pull_parser() {
    let base: &[u8] = br#"{"id": 9, "tokens": [1, 2, 3, 4], "gen_len": 3,
        "budget": 0.25, "slo": "quality", "extra": {"a": [true, null, "xA"]}}"#;
    let mut rng = Rng::new(0x714);
    let mut slot = wire::RequestSlot::with_capacity(16);
    for _ in 0..2000 {
        let mut body = base.to_vec();
        if rng.below(4) == 0 {
            body.truncate(rng.below(body.len() + 1));
        } else {
            for _ in 0..1 + rng.below(8) {
                let i = rng.below(body.len());
                body[i] = rng.below(256) as u8;
            }
        }
        let _ = wire::decode_request_json(&body, 16, &mut slot);
        // The raw event stream must also terminate (End or Err) in a
        // bounded number of steps.
        let mut p = PullParser::new(&body);
        let mut steps = 0usize;
        loop {
            match p.next() {
                Ok(Event::End) | Err(_) => break,
                Ok(_) => {
                    steps += 1;
                    assert!(steps <= 4 * base.len(), "event stream failed to terminate");
                }
            }
        }
    }
}
