//! Integration: the elastic serving coordinator end to end over a synthetic
//! trace, on the native kernel backend — runs fully offline (no artifacts,
//! no PJRT).

use flexrank::coordinator::{serve_trace, PolicyKind, ServeCfg, SubmodelRegistry};
use flexrank::data::trace::Slo;
use flexrank::data::{Corpus, TraceCfg, TraceGen};
use flexrank::runtime::ModelConfig;
use flexrank::training::params::{decompose_teacher, random_teacher, student_from_factors};

fn setup() -> (ModelConfig, SubmodelRegistry) {
    let cfg = flexrank::config::load_model_config("tiny").expect("configs/model_tiny.json");
    let teacher = random_teacher(&cfg, 42);
    let factors = decompose_teacher(&cfg, &teacher, None).unwrap();
    let student = student_from_factors(&cfg, &teacher, &factors).unwrap();
    let registry = SubmodelRegistry::load_native(&cfg, &student, None).unwrap();
    (cfg, registry)
}

fn trace(cfg: &ModelConfig, n: usize, rate: f64) -> Vec<flexrank::data::Request> {
    let corpus = Corpus::generate(50_000, 5);
    TraceGen::new(
        TraceCfg {
            n_requests: n,
            rate,
            seq_len: cfg.seq_len,
            vocab: cfg.vocab,
            seed: 11,
            ..Default::default()
        },
        &corpus.heldout,
    )
    .expect("trace cfg must validate")
    .generate()
}

#[test]
fn serves_every_request_exactly_once() {
    let (cfg, mut registry) = setup();
    let t = trace(&cfg, 60, 500.0);
    let report = serve_trace(
        &mut registry,
        t,
        &ServeCfg { policy: PolicyKind::Static, max_wait_ms: 2.0, replay_speed: 0.0, ..Default::default() },
    )
    .unwrap();
    assert_eq!(report.metrics.requests_done, 60);
    assert_eq!(report.tier_requests.iter().sum::<usize>(), 60);
    assert!(report.metrics.batches >= 60 / cfg.batch_serve);
}

#[test]
fn quality_requests_go_to_biggest_tier_statically() {
    let (cfg, mut registry) = setup();
    let mut t = trace(&cfg, 24, 1000.0);
    for r in &mut t {
        r.slo = Slo::Quality;
    }
    let report = serve_trace(
        &mut registry,
        t,
        &ServeCfg { policy: PolicyKind::Static, max_wait_ms: 2.0, replay_speed: 0.0, ..Default::default() },
    )
    .unwrap();
    let last = report.tier_requests.len() - 1;
    assert_eq!(report.tier_requests[last], 24);
}

#[test]
fn adaptive_policy_sheds_load_downward() {
    let (cfg, mut registry) = setup();
    // As-fast-as-possible replay creates queue pressure immediately; run the
    // identical trace under both policies and compare top-tier routing.
    let serve = |registry: &mut SubmodelRegistry, policy| {
        serve_trace(
            registry,
            trace(&cfg, 120, 1e9),
            &ServeCfg { policy, max_wait_ms: 1.0, replay_speed: 0.0, ..Default::default() },
        )
        .unwrap()
    };
    let stat = serve(&mut registry, PolicyKind::Static);
    let adap = serve(&mut registry, PolicyKind::Adaptive);
    assert_eq!(stat.metrics.requests_done, 120);
    assert_eq!(adap.metrics.requests_done, 120);
    let last = cfg.serve_tiers.len() - 1;
    // Static routes every quality request to the top tier regardless of
    // load; adaptive must demote at least some of them under pressure.
    assert!(stat.tier_requests[last] > 0, "static: {:?}", stat.tier_requests);
    assert!(
        adap.tier_requests[last] < stat.tier_requests[last],
        "adaptive should shift mass down: adaptive {:?} vs static {:?}",
        adap.tier_requests,
        stat.tier_requests
    );
}

#[test]
fn serving_hot_path_reuses_scratch() {
    let (cfg, mut registry) = setup();
    // Warm up once, then assert the shared scratch never reallocates over a
    // full serving run (the zero-per-request-allocation invariant).
    let warm = vec![0i32; cfg.batch_serve * cfg.seq_len];
    registry.infer(0, &warm).unwrap();
    let fp = registry.scratch_fingerprint();
    let t = trace(&cfg, 40, 1e9);
    let report = serve_trace(
        &mut registry,
        t,
        &ServeCfg { policy: PolicyKind::Static, max_wait_ms: 1.0, replay_speed: 0.0, ..Default::default() },
    )
    .unwrap();
    assert_eq!(report.metrics.requests_done, 40);
    assert_eq!(registry.scratch_fingerprint(), fp, "hot path must not reallocate");
}
