//! Integration: the elastic serving coordinator end to end over a synthetic
//! trace (requires `make artifacts`).

use flexrank::coordinator::{serve_trace, PolicyKind, ServeCfg};
use flexrank::data::trace::Slo;
use flexrank::data::{Corpus, TraceCfg, TraceGen};
use flexrank::runtime::Engine;
use flexrank::training::params::{decompose_teacher, student_from_factors, ParamSet};

fn setup() -> (Engine, ParamSet) {
    let e = Engine::new(flexrank::artifacts_dir()).expect("run `make artifacts` first");
    let cfg = e.manifest.config.clone();
    let teacher = ParamSet::from_specs(
        &e.manifest.teacher_init,
        e.manifest.load_teacher_init().unwrap(),
    );
    let factors = decompose_teacher(&cfg, &teacher, None).unwrap();
    let student = student_from_factors(&cfg, &teacher, &factors).unwrap();
    (e, student)
}

fn trace(e: &Engine, n: usize, rate: f64) -> Vec<flexrank::data::Request> {
    let cfg = e.manifest.config.clone();
    let corpus = Corpus::generate(50_000, 5);
    TraceGen::new(
        TraceCfg {
            n_requests: n,
            rate,
            seq_len: cfg.seq_len,
            vocab: cfg.vocab,
            seed: 11,
            ..Default::default()
        },
        &corpus.heldout,
    )
    .generate()
}

#[test]
#[cfg_attr(debug_assertions, ignore = "slow under debug; run via `cargo test --release` (make test)")]
fn serves_every_request_exactly_once() {
    let (e, student) = setup();
    let t = trace(&e, 60, 500.0);
    let report = serve_trace(
        &e,
        &student,
        t,
        &ServeCfg { policy: PolicyKind::Static, max_wait_ms: 2.0, replay_speed: 0.0 },
    )
    .unwrap();
    assert_eq!(report.metrics.requests_done, 60);
    assert_eq!(report.tier_requests.iter().sum::<usize>(), 60);
    assert!(report.metrics.batches >= 60 / e.manifest.config.batch_serve);
}

#[test]
#[cfg_attr(debug_assertions, ignore = "slow under debug; run via `cargo test --release` (make test)")]
fn quality_requests_go_to_biggest_tier_statically() {
    let (e, student) = setup();
    let mut t = trace(&e, 24, 1000.0);
    for r in &mut t {
        r.slo = Slo::Quality;
    }
    let report = serve_trace(
        &e,
        &student,
        t,
        &ServeCfg { policy: PolicyKind::Static, max_wait_ms: 2.0, replay_speed: 0.0 },
    )
    .unwrap();
    let last = report.tier_requests.len() - 1;
    assert_eq!(report.tier_requests[last], 24);
}

#[test]
#[cfg_attr(debug_assertions, ignore = "slow under debug; run via `cargo test --release` (make test)")]
fn adaptive_policy_sheds_load_downward() {
    let (e, student) = setup();
    // As-fast-as-possible replay creates queue pressure immediately.
    let t = trace(&e, 120, 1e9);
    let report = serve_trace(
        &e,
        &student,
        t,
        &ServeCfg { policy: PolicyKind::Adaptive, max_wait_ms: 1.0, replay_speed: 0.0 },
    )
    .unwrap();
    // Under pressure the adaptive policy must route strictly more requests
    // to lower tiers than the static SLO map would (static: 50/30/20 split
    // over interactive/standard/quality at tiers 0/1/3).
    assert!(report.tier_requests[0] > 0);
    let low = report.tier_requests[0] + report.tier_requests[1];
    let high: usize = report.tier_requests[2..].iter().sum();
    assert!(low > high, "adaptive should shift mass down: {:?}", report.tier_requests);
    assert_eq!(report.metrics.requests_done, 120);
}

#[test]
#[cfg_attr(debug_assertions, ignore = "slow under debug; run via `cargo test --release` (make test)")]
fn smaller_tiers_execute_faster() {
    let (e, student) = setup();
    let mut t = trace(&e, 40, 1e9);
    // Alternate strictly between the smallest and largest tier via budgets.
    for (i, r) in t.iter_mut().enumerate() {
        r.budget = Some(if i % 2 == 0 { 0.01 } else { 1.0 });
    }
    let report = serve_trace(
        &e,
        &student,
        t,
        &ServeCfg { policy: PolicyKind::Static, max_wait_ms: 1.0, replay_speed: 0.0 },
    )
    .unwrap();
    let small = report.metrics.tier_exec(0).p50_ms;
    let big = report.metrics.tier_exec(report.tier_budgets.len() - 1).p50_ms;
    assert!(small > 0.0 && big > 0.0);
    assert!(
        small < big,
        "tier0 exec {small}ms should beat tier3 {big}ms"
    );
}
