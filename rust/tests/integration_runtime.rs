#![cfg(feature = "pjrt")]

//! Cross-layer integration tests: rust ⇄ AOT artifacts ⇄ PJRT.
//!
//! Require `make artifacts` (base config) to have run — the Makefile's
//! `test` target guarantees that ordering.

use flexrank::flexrank::masks::{profile_to_masks, uniform_profile};
use flexrank::runtime::{Engine, Tensor};
use flexrank::training::params::{
    decompose_teacher, gar_params_for, student_from_factors, ParamSet,
};

fn engine() -> Engine {
    Engine::new(flexrank::artifacts_dir()).expect("run `make artifacts` first")
}

fn teacher(engine: &Engine) -> ParamSet {
    ParamSet::from_specs(
        &engine.manifest.teacher_init,
        engine.manifest.load_teacher_init().unwrap(),
    )
}

#[test]
fn teacher_fwd_produces_finite_logits() {
    let e = engine();
    let cfg = e.manifest.config.clone();
    let exe = e.load("teacher_fwd").unwrap();
    let mut inputs = teacher(&e).ordered_for(&exe.spec, 0).unwrap();
    inputs.push(Tensor::i32(
        vec![cfg.batch_eval, cfg.seq_len],
        vec![7; cfg.batch_eval * cfg.seq_len],
    ));
    let out = exe.run(&inputs).unwrap();
    assert_eq!(out[0].shape(), &[cfg.batch_eval, cfg.seq_len, cfg.vocab]);
    assert!(out[0].as_f32().unwrap().iter().all(|x| x.is_finite()));
}

#[test]
#[cfg_attr(debug_assertions, ignore = "slow under debug; run via `cargo test --release` (make test)")]
fn student_full_rank_matches_teacher_through_pjrt() {
    // The whole chain: rust SVD decomposition -> student params -> masked
    // student executable must reproduce the dense teacher executable.
    let e = engine();
    let cfg = e.manifest.config.clone();
    let t = teacher(&e);
    let factors = decompose_teacher(&cfg, &t, None).unwrap();
    let student = student_from_factors(&cfg, &t, &factors).unwrap();

    let tok = Tensor::i32(
        vec![cfg.batch_eval, cfg.seq_len],
        (0..cfg.batch_eval * cfg.seq_len).map(|i| (i % cfg.vocab) as i32).collect(),
    );

    let te = e.load("teacher_fwd").unwrap();
    let mut ti = t.ordered_for(&te.spec, 0).unwrap();
    ti.push(tok.clone());
    let t_logits = te.run(&ti).unwrap();

    let se = e.load("student_logits").unwrap();
    let mut si = student.ordered_for(&se.spec, 0).unwrap();
    si.push(Tensor::f32(
        vec![cfg.n_blocks, 4, cfg.rank_full()],
        profile_to_masks(&uniform_profile(cfg.n_fact_layers(), cfg.rank_full()), cfg.rank_full()),
    ));
    si.push(tok);
    let s_logits = se.run(&si).unwrap();

    let a = t_logits[0].as_f32().unwrap();
    let b = s_logits[0].as_f32().unwrap();
    let max_err = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 5e-3, "teacher/student divergence {max_err}");
}

#[test]
#[cfg_attr(debug_assertions, ignore = "slow under debug; run via `cargo test --release` (make test)")]
fn gar_serving_matches_masked_student() {
    // GAR extraction in rust + the GAR serving executable must agree with
    // the masked student executable at the tier profile.
    let e = engine();
    let cfg = e.manifest.config.clone();
    let t = teacher(&e);
    let factors = decompose_teacher(&cfg, &t, None).unwrap();
    let student = student_from_factors(&cfg, &t, &factors).unwrap();

    let serve = e.load("serve_gar_t1").unwrap();
    let profile = serve.spec.profile.clone().unwrap();
    let gar = gar_params_for(&cfg, &student, &serve.spec).unwrap();

    let tok = Tensor::i32(
        vec![cfg.batch_serve, cfg.seq_len],
        (0..cfg.batch_serve * cfg.seq_len).map(|i| ((i * 3) % cfg.vocab) as i32).collect(),
    );
    let mut gi = gar.clone();
    gi.push(tok.clone());
    let g_logits = serve.run(&gi).unwrap();

    let se = e.load("student_logits").unwrap();
    let mut si = student.ordered_for(&se.spec, 0).unwrap();
    si.push(Tensor::f32(
        vec![cfg.n_blocks, 4, cfg.rank_full()],
        profile_to_masks(&profile, cfg.rank_full()),
    ));
    // student_logits is lowered at batch_eval; replicate serve batch rows.
    let mut tok_eval = tok.as_i32().unwrap().to_vec();
    while tok_eval.len() < cfg.batch_eval * cfg.seq_len {
        let row = tok_eval[..cfg.seq_len].to_vec();
        tok_eval.extend(row);
    }
    si.push(Tensor::i32(vec![cfg.batch_eval, cfg.seq_len], tok_eval));
    let s_logits = se.run(&si).unwrap();

    let a = g_logits[0].as_f32().unwrap();
    let b = s_logits[0].as_f32().unwrap();
    let n = cfg.batch_serve * cfg.seq_len * cfg.vocab;
    let max_err = a[..n]
        .iter()
        .zip(&b[..n])
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 5e-3, "gar/masked divergence {max_err}");
}

#[test]
#[cfg_attr(debug_assertions, ignore = "slow under debug; run via `cargo test --release` (make test)")]
fn run_b_device_resident_path_matches_host_path() {
    let e = engine();
    let exe = e.load("teacher_fwd").unwrap();
    let cfg = e.manifest.config.clone();
    let mut inputs = teacher(&e).ordered_for(&exe.spec, 0).unwrap();
    inputs.push(Tensor::i32(
        vec![cfg.batch_eval, cfg.seq_len],
        vec![42; cfg.batch_eval * cfg.seq_len],
    ));
    let host_out = exe.run(&inputs).unwrap();

    let bufs = e.to_device_all(&inputs).unwrap();
    let refs: Vec<&xla::PjRtBuffer> = bufs.iter().map(|d| d.buffer()).collect();
    let dev_out = exe.run_b(&refs).unwrap();
    let dev_t = Tensor::from_literal(&dev_out[0]).unwrap();
    assert_eq!(host_out[0].as_f32().unwrap(), dev_t.as_f32().unwrap());
}

#[test]
fn manifest_rejects_wrong_shapes() {
    let e = engine();
    let exe = e.load("teacher_fwd").unwrap();
    let cfg = e.manifest.config.clone();
    let mut inputs = teacher(&e).ordered_for(&exe.spec, 0).unwrap();
    // Wrong token shape must be caught by the spec check, not by XLA.
    inputs.push(Tensor::i32(vec![1, cfg.seq_len], vec![0; cfg.seq_len]));
    assert!(exe.run(&inputs).is_err());
}

#[test]
#[cfg_attr(debug_assertions, ignore = "slow under debug; run via `cargo test --release` (make test)")]
fn kd_train_step_first_loss_is_zero_at_full_rank() {
    // Student initialized from the teacher's exact factorization ⇒ KD loss
    // of the very first consolidation step must be ~0 (Eq. 5 at θ ≈ θ_orig).
    let e = engine();
    let cfg = e.manifest.config.clone();
    let t = teacher(&e);
    let factors = decompose_teacher(&cfg, &t, None).unwrap();
    let student = student_from_factors(&cfg, &t, &factors).unwrap();
    let exe = e.load("kd_train_step").unwrap();
    let spec = exe.spec.clone();

    let mut inputs = student.ordered_for(&spec, 0).unwrap();
    inputs.extend(student.zeros_like().ordered_for(&spec, 1).unwrap());
    inputs.extend(student.zeros_like().ordered_for(&spec, 2).unwrap());
    inputs.push(Tensor::scalar_f32(1.0));
    inputs.extend(t.ordered_for(&spec, 4).unwrap());
    inputs.push(Tensor::f32(
        vec![cfg.n_blocks, 4, cfg.rank_full()],
        profile_to_masks(&uniform_profile(cfg.n_fact_layers(), cfg.rank_full()), cfg.rank_full()),
    ));
    inputs.push(Tensor::i32(
        vec![cfg.batch_train, cfg.seq_len + 1],
        (0..cfg.batch_train * (cfg.seq_len + 1)).map(|i| (i % cfg.vocab) as i32).collect(),
    ));
    let out = exe.run(&inputs).unwrap();
    let loss = out.last().unwrap().item_f32().unwrap();
    assert!(loss.abs() < 1e-3, "first KD loss {loss}");
}
