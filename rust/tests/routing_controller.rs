//! Routing/controller suite: the hysteresis and never-demote contracts of
//! the elastic tier controller, pinned as properties, plus an end-to-end
//! elastic-vs-adaptive comparison under bursty overload.
//!
//! The headline pins (ISSUE acceptance):
//! * hysteresis bounds the controller to ≤ 1 level change per dwell window
//!   while the stateless adaptive policy measurably flaps on the same
//!   oscillating depth sequence;
//! * an explicit-budget request is never demoted, at any pressure;
//! * the settled demotion level is monotone in sustained load;
//! * under bursty overload with a shed bound, Elastic is not Pareto-worse
//!   than Adaptive on (shed, p99), and its demote-before-shed machinery
//!   actually engages (demotions > 0, switches ≥ 1).

use std::time::{Duration, Instant};

use flexrank::coordinator::{
    serve_trace, Policy, PolicyKind, PressureBand, ServeCfg, SubmodelRegistry, TierRouter,
};
use flexrank::data::trace::Slo;
use flexrank::data::{ArrivalShape, Corpus, Request, TraceCfg, TraceGen};
use flexrank::runtime::{ModelConfig, ServingBackend};
use flexrank::training::params::{decompose_teacher, random_teacher, student_from_factors};

fn req(slo: Slo) -> Request {
    Request { id: 0, arrival_s: 0.0, slo, tokens: vec![0i32; 4], gen_len: 0, budget: None }
}

fn elastic_router(n_tiers: usize, dwell_ms: u64) -> TierRouter {
    TierRouter::new(
        PolicyKind::Elastic,
        n_tiers,
        PressureBand::new(24, 4).unwrap(),
        Duration::from_millis(dwell_ms),
        0.0,
        &[],
    )
    .unwrap()
}

/// Hysteresis acceptance pin: an oscillating queue depth straddling both
/// thresholds (hot ↔ calm every observation) changes the elastic level at
/// most once per dwell window, while the stateless adaptive `select` flips
/// its answer on nearly every observation of the same sequence.
#[test]
fn hysteresis_bounds_switches_while_stateless_policy_flaps() {
    const DWELL_MS: u64 = 10;
    const STEP_MS: u64 = 1;
    const STEPS: u64 = 200;
    let windows = (STEPS * STEP_MS) / DWELL_MS; // 20 dwell windows

    let mut router = elastic_router(4, DWELL_MS);
    let stateless = Policy::new(PolicyKind::Adaptive, 4);
    let standard = req(Slo::Standard);

    let t0 = Instant::now();
    let mut stateless_flips = 0usize;
    let mut prev_pick: Option<usize> = None;
    for k in 0..STEPS {
        // Above hi (25) on even ticks, full calm (0) on odd ticks: the
        // worst-case flapping load for a threshold rule.
        let depth = if k % 2 == 0 { 25 } else { 0 };
        let now = t0 + Duration::from_millis(k * STEP_MS);
        router.observe(now, depth);
        let pick = stateless.select(&standard, depth);
        if prev_pick.is_some_and(|p| p != pick) {
            stateless_flips += 1;
        }
        prev_pick = Some(pick);
    }

    // ≤ 1 switch per dwell window (+1 for the ungated first observation).
    assert!(
        router.tier_switches() <= windows + 1,
        "elastic flapped: {} switches over {} dwell windows",
        router.tier_switches(),
        windows
    );
    // The same sequence makes the stateless policy change its answer on
    // every tick — the bug class the controller exists to fix.
    assert!(
        stateless_flips as u64 >= 5 * (windows + 1),
        "expected the stateless policy to flap (got {stateless_flips} flips \
         vs {} elastic switches)",
        router.tier_switches()
    );
}

/// Explicit-budget contract under arbitrary pressure: whatever level the
/// controller reaches, a budget-carrying request routes to its contracted
/// tier with `requested == served`.
#[test]
fn property_budget_requests_never_demoted() {
    flexrank::prop::forall(
        144,
        120,
        |rng| {
            let n_tiers = 2 + rng.below(6);
            let budget = (1 + rng.below(100)) as f64 / 100.0; // (0, 1]
            let depth = rng.below(4096);
            let heat_steps = rng.below(24);
            (n_tiers, budget, depth, heat_steps)
        },
        |(n_tiers, budget, depth, heat_steps)| {
            let mut router = elastic_router(*n_tiers, 1);
            let t0 = Instant::now();
            // Sustained overload first, so the demotion level is nonzero
            // whenever heat_steps allows it.
            for k in 0..*heat_steps as u64 {
                router.observe(t0 + Duration::from_millis(2 * k), 10_000);
            }
            let mut r = req(Slo::Quality);
            r.budget = Some(*budget);
            let d = router.route(&r, *depth, t0 + Duration::from_secs(1));
            if d.requested != d.served {
                return Err(format!("budget {budget} demoted: {d:?}"));
            }
            let expect = ((budget * *n_tiers as f64).ceil() as usize).clamp(1, *n_tiers) - 1;
            if d.served != expect {
                return Err(format!("budget {budget} -> tier {} (want {expect})", d.served));
            }
            Ok(())
        },
    );
}

/// Monotonicity through the router facade: with heavier sustained load the
/// settled served tier for a Quality request never rises.
#[test]
fn property_served_tier_monotone_under_sustained_load() {
    flexrank::prop::forall(
        145,
        60,
        |rng| {
            let n_tiers = 2 + rng.below(4);
            let d1 = rng.below(100);
            let d2 = d1 + rng.below(100);
            (n_tiers, d1, d2)
        },
        |(n_tiers, d1, d2)| {
            let settle = |depth: usize| {
                let mut router = elastic_router(*n_tiers, 1);
                let t0 = Instant::now();
                for k in 0..24u64 {
                    router.observe(t0 + Duration::from_millis(2 * k), depth);
                }
                router.route(&req(Slo::Quality), depth, t0 + Duration::from_secs(1)).served
            };
            let (s1, s2) = (settle(*d1), settle(*d2));
            if s2 > s1 {
                return Err(format!(
                    "served tier rose under heavier load: depth {d1}->{s1}, {d2}->{s2}"
                ));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// End-to-end: bursty overload through serve_trace with an explicit shed
// bound.  Release-only (the debug-build kernel path is too slow to create
// honest overload dynamics).

fn tiny_registry(seed: u64) -> (ModelConfig, SubmodelRegistry) {
    let cfg = flexrank::config::load_model_config("tiny").expect("configs/model_tiny.json");
    let teacher = random_teacher(&cfg, seed);
    let factors = decompose_teacher(&cfg, &teacher, None).unwrap();
    let student = student_from_factors(&cfg, &teacher, &factors).unwrap();
    let registry = SubmodelRegistry::load_native(&cfg, &student, None).unwrap();
    (cfg, registry)
}

fn bursty_trace(cfg: &ModelConfig, n: usize, seed: u64) -> Vec<Request> {
    let corpus = Corpus::generate(50_000, 5);
    TraceGen::new(
        TraceCfg {
            n_requests: n,
            rate: 900.0,
            seq_len: cfg.seq_len,
            vocab: cfg.vocab,
            seed,
            // Short on/off cycles so one test run spans several of them.
            shape: ArrivalShape::Bursty { burst_s: 0.015, idle_s: 0.03, mult: 6.0 },
            ..Default::default()
        },
        &corpus.heldout,
    )
    .expect("trace cfg must validate")
    .generate()
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-only: needs realistic service rates")]
fn elastic_demotes_before_shedding_under_bursty_overload() {
    let (cfg, mut registry) = tiny_registry(77);
    let n = 160;
    let queue_cap = 2 * registry.batch();
    let run = |registry: &mut SubmodelRegistry, policy| {
        serve_trace(
            registry,
            bursty_trace(&cfg, n, 21),
            &ServeCfg {
                policy,
                max_wait_ms: 1.0,
                // Flood replay: guaranteed overload regardless of how fast
                // this machine serves, so the controller must engage.
                replay_speed: 0.0,
                queue_cap,
                dwell_ms: 4.0,
                ..Default::default()
            },
        )
        .unwrap()
    };
    let adap = run(&mut registry, PolicyKind::Adaptive);
    let elas = run(&mut registry, PolicyKind::Elastic);

    // Shed-explicit accounting: every arrival is either served or counted
    // shed — nothing vanishes.
    assert_eq!(adap.metrics.requests_done + adap.shed, n, "adaptive accounting");
    assert_eq!(elas.metrics.requests_done + elas.shed, n, "elastic accounting");

    // Static/Adaptive never touch the controller.
    assert_eq!(adap.tier_switches, 0);

    // The elastic machinery must actually engage under this load...
    assert!(
        elas.metrics.demotions > 0,
        "elastic never demoted under bursty overload: {:?}",
        elas.metrics.requested_by_tier
    );
    // ...within the hysteresis bound (≤ 1 switch per dwell window).
    let max_switches = (elas.wall_s * 1000.0 / 4.0).ceil() as u64 + 1;
    assert!(
        elas.tier_switches <= max_switches,
        "elastic flapped e2e: {} switches in {:.2}s",
        elas.tier_switches,
        elas.wall_s
    );

    // Pareto: demote-before-shed must not lose on both axes at once.
    let p99 = |r: &flexrank::coordinator::ServeReport| {
        let mut all: Vec<f64> = Vec::new();
        for t in 0..r.tier_budgets.len() {
            all.extend(r.metrics.latency_ms[t].iter());
        }
        flexrank::coordinator::LatencyStats::from_samples(&all).p99_ms
    };
    // (Small slack absorbs scheduler jitter; the strict dominance check is
    // the serving bench's Pareto verdict, which runs timed bursty replay.)
    let (ap99, ep99) = (p99(&adap), p99(&elas));
    assert!(
        elas.shed <= adap.shed + n / 20 || ep99 <= ap99 * 1.1,
        "elastic Pareto-dominated by adaptive: shed {} vs {}, p99 {ep99:.1}ms vs {ap99:.1}ms",
        elas.shed,
        adap.shed
    );
}

/// The decode path threads the same router: an elastic decode run over a
/// flooded variable-length trace reports its routing columns coherently.
#[test]
#[cfg_attr(debug_assertions, ignore = "release-only: decode path under load")]
fn decode_path_reports_elastic_routing() {
    let (cfg, mut registry) = tiny_registry(78);
    let corpus = Corpus::generate(50_000, 5);
    let trace = TraceGen::new(
        TraceCfg {
            n_requests: 48,
            rate: 2000.0,
            seq_len: cfg.seq_len,
            vocab: cfg.vocab,
            seed: 31,
            prompt_len_min: (cfg.seq_len / 8).max(1),
            prompt_len_max: cfg.seq_len,
            gen_len_min: 1,
            gen_len_max: (cfg.seq_len / 2).max(1),
            shape: ArrivalShape::Bursty { burst_s: 0.01, idle_s: 0.02, mult: 8.0 },
            ..Default::default()
        },
        &corpus.heldout,
    )
    .expect("trace cfg must validate")
    .generate();
    let report = flexrank::coordinator::serve_trace_decode(
        &mut registry,
        trace,
        &ServeCfg {
            policy: PolicyKind::Elastic,
            max_wait_ms: 1.0,
            replay_speed: 0.0,
            queue_cap: 2 * registry.batch(),
            dwell_ms: 2.0,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(report.requests_done + report.shed, 48, "decode accounting");
    assert!(report.eval_loss_proxy().is_finite());
    assert!(report.shed_rate() >= 0.0 && report.shed_rate() <= 1.0);
    // The emitted JSON must carry the routing columns.
    let json = report.to_json();
    for key in ["shed", "demotions", "tier_switches", "eval_loss_proxy"] {
        assert!(json.contains(&format!("\"{key}\"")), "missing {key} in {json}");
    }
}
