//! The attention equivalence suite: streaming (flash-style) ≡ blocked ≡
//! scalar reference, over a randomized shape grid that includes every
//! adversarial corner — `seq` not a multiple of the tile, `seq == 1`,
//! `tile >= seq`, a single workspace slot, and head widths that are not a
//! multiple of the kernels' 4-wide unroll.  Plus finite-difference checks
//! of the recompute-based streaming backward on every gradient path
//! (dQ, dK, dV), a cross-path pin of streaming grads against the
//! retained-probs backward, and the workspace memory contract (nothing
//! quadratic in `seq`, no reallocation across calls).
//!
//! This file is the pin that lets the serving/training crossover knob flip
//! between the two formulations safely: everything downstream (DP probe
//! losses, KD gradients, served logits) is identical to f32 rounding.

use flexrank::prop::forall;
use flexrank::rng::Rng;
use flexrank::runtime::attention::{
    causal_attention, causal_attention_backward, causal_attention_backward_streaming,
    AttnGradWorkspace, AttnWorkspace,
};

/// Scalar causal softmax-attention recurrence with f64 accumulation — the
/// oracle both blocked formulations must reproduce.  f64 sums make the
/// oracle itself exact to well below the 1e-5 gate, so the gate measures
/// only the kernels' re-association error.
fn scalar_reference(qkv: &[f32], batch: usize, t_len: usize, d: usize, heads: usize) -> Vec<f32> {
    let hd = d / heads;
    let w3 = 3 * d;
    let scale = 1.0 / (hd as f64).sqrt();
    let mut att = vec![0f32; batch * t_len * d];
    for b in 0..batch {
        let base = b * t_len;
        for head in 0..heads {
            let (qo, ko, vo) = (head * hd, d + head * hd, 2 * d + head * hd);
            for t1 in 0..t_len {
                let q = &qkv[(base + t1) * w3 + qo..(base + t1) * w3 + qo + hd];
                let mut sc = vec![0f64; t1 + 1];
                let mut mx = f64::NEG_INFINITY;
                for (t2, s) in sc.iter_mut().enumerate() {
                    let k = &qkv[(base + t2) * w3 + ko..(base + t2) * w3 + ko + hd];
                    *s = q.iter().zip(k).map(|(a, b)| *a as f64 * *b as f64).sum::<f64>() * scale;
                    mx = mx.max(*s);
                }
                let mut sum = 0f64;
                for s in sc.iter_mut() {
                    *s = (*s - mx).exp();
                    sum += *s;
                }
                for j in 0..hd {
                    let mut o = 0f64;
                    for (t2, w) in sc.iter().enumerate() {
                        o += w / sum * qkv[(base + t2) * w3 + vo + j] as f64;
                    }
                    att[(base + t1) * d + head * hd + j] = o as f32;
                }
            }
        }
    }
    att
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{what}: length {} vs {}", got.len(), want.len()));
    }
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let scale = 1.0f32.max(w.abs());
        if (g - w).abs() > tol * scale {
            return Err(format!("{what}[{i}]: {g} vs {w} (tol {tol})"));
        }
    }
    Ok(())
}

#[test]
fn property_streaming_blocked_scalar_three_way_equivalence() {
    // Randomized (batch, heads, hd, seq, slots, tile): the streaming and
    // blocked paths must both match the f64 scalar oracle to 1e-5 and each
    // other, for every workspace slot count and tile width — including
    // tiles that do not divide seq, tiles wider than seq, and head widths
    // off the 4-wide unroll.
    forall(
        711,
        60,
        |rng| {
            let batch = 1 + rng.below(3);
            let heads = 1 + rng.below(4);
            // 1..=9 covers hd % 4 ∈ {0,1,2,3} (adversarial unroll widths).
            let hd = 1 + rng.below(9);
            let t_len = 1 + rng.below(33); // includes seq == 1
            let slots = 1 + rng.below(8); // includes a single slot
            let tile = 1 + rng.below(t_len + 8); // includes tile >= seq
            let d = heads * hd;
            let qkv: Vec<f32> = (0..batch * t_len * 3 * d).map(|_| rng.normal() as f32).collect();
            (batch, heads, t_len, slots, tile, qkv)
        },
        |(batch, heads, t_len, slots, tile, qkv)| {
            let (batch, heads, t_len) = (*batch, *heads, *t_len);
            let d = qkv.len() / (batch * t_len * 3);
            let want = scalar_reference(qkv, batch, t_len, d, heads);
            let hd = d / heads;

            let mut att = vec![0f32; batch * t_len * d];
            let mut ws_b = AttnWorkspace::new(t_len, hd, *slots);
            causal_attention(qkv, batch, t_len, d, heads, &mut ws_b, &mut att, None);
            assert_close(&att, &want, 1e-5, "blocked vs scalar")?;
            let blocked = att.clone();

            let mut ws_s = AttnWorkspace::new_streaming(t_len, hd, *slots, *tile);
            causal_attention(qkv, batch, t_len, d, heads, &mut ws_s, &mut att, None);
            assert_close(&att, &want, 1e-5, "streaming vs scalar")?;
            assert_close(&att, &blocked, 1e-5, "streaming vs blocked")?;
            Ok(())
        },
    );
}

#[test]
fn property_streaming_backward_matches_retained_backward() {
    // Cross-path gradient pin over the same adversarial grid: the
    // recompute-based streaming backward ≡ the retained-probs backward for
    // all of dQ, dK, dV (they live in the three thirds of dqkv).
    forall(
        712,
        30,
        |rng| {
            let batch = 1 + rng.below(2);
            let heads = 1 + rng.below(3);
            let hd = 1 + rng.below(7);
            let t_len = 1 + rng.below(19);
            let slots = 1 + rng.below(6);
            let tile = 1 + rng.below(t_len + 4);
            let d = heads * hd;
            let qkv: Vec<f32> = (0..batch * t_len * 3 * d).map(|_| rng.normal() as f32).collect();
            let datt: Vec<f32> = (0..batch * t_len * d).map(|_| rng.normal() as f32).collect();
            (batch, heads, t_len, slots, tile, qkv, datt)
        },
        |(batch, heads, t_len, slots, tile, qkv, datt)| {
            let (batch, heads, t_len) = (*batch, *heads, *t_len);
            let d = qkv.len() / (batch * t_len * 3);
            let hd = d / heads;

            let mut ws = AttnWorkspace::new(t_len, hd, *slots);
            let mut att = vec![0f32; batch * t_len * d];
            let mut probs = vec![0f32; batch * heads * t_len * t_len];
            causal_attention(qkv, batch, t_len, d, heads, &mut ws, &mut att, Some(&mut probs));
            let mut want = vec![0f32; batch * t_len * 3 * d];
            let mut gws = AttnGradWorkspace::new(t_len, hd, *slots);
            causal_attention_backward(
                qkv, &probs, datt, batch, t_len, d, heads, &mut gws, &mut want,
            );

            let mut got = vec![0f32; batch * t_len * 3 * d];
            let mut sgws = AttnGradWorkspace::new_streaming(t_len, hd, *slots, *tile);
            causal_attention_backward_streaming(
                qkv, datt, batch, t_len, d, heads, &mut sgws, &mut got,
            );
            assert_close(&got, &want, 1e-4, "streaming vs retained dqkv")
        },
    );
}

#[test]
fn streaming_backward_matches_finite_difference_on_every_path() {
    // Central differences through the *streaming* forward for
    // L = Σ coef·att, probing indices in each of the q, k, and v thirds of
    // every row so all three gradient paths of the recompute backward are
    // exercised — across tiles that split the sequence unevenly.
    let (batch, heads, hd, t_len) = (2usize, 2usize, 3usize, 7usize);
    let d = heads * hd;
    let mut rng = Rng::new(713);
    let mut qkv: Vec<f32> = (0..batch * t_len * 3 * d).map(|_| rng.normal() as f32).collect();
    let coef: Vec<f32> = (0..batch * t_len * d).map(|_| rng.normal() as f32).collect();

    for (tile, slots) in [(1usize, 2usize), (3, 1), (4, 4), (16, 2)] {
        let mut ws = AttnWorkspace::new_streaming(t_len, hd, slots, tile);
        let mut gws = AttnGradWorkspace::new_streaming(t_len, hd, slots, tile);
        let loss = |qkv: &[f32], ws: &mut AttnWorkspace| -> f32 {
            let mut att = vec![0f32; batch * t_len * d];
            causal_attention(qkv, batch, t_len, d, heads, ws, &mut att, None);
            att.iter().zip(&coef).map(|(a, c)| a * c).sum()
        };
        let mut dqkv = vec![0f32; batch * t_len * 3 * d];
        causal_attention_backward_streaming(
            &qkv, &coef, batch, t_len, d, heads, &mut gws, &mut dqkv,
        );

        let eps = 1e-2f32;
        // One probe in each third (q, k, v) of several rows: row 0 (first
        // tile), a mid row, and the last row of the last batch.
        let rows = [0usize, t_len / 2, batch * t_len - 1];
        for &row in &rows {
            for (third, off) in [(0usize, 0usize), (1, d), (2, 2 * d)] {
                let idx = row * 3 * d + off + (row + third) % d;
                let orig = qkv[idx];
                qkv[idx] = orig + eps;
                let lp = loss(&qkv, &mut ws);
                qkv[idx] = orig - eps;
                let lm = loss(&qkv, &mut ws);
                qkv[idx] = orig;
                let num = (lp - lm) / (2.0 * eps);
                assert!(
                    (num - dqkv[idx]).abs() < 2e-2 + 0.05 * dqkv[idx].abs(),
                    "tile {tile} slots {slots} third {third} dqkv[{idx}]: \
                     numeric {num} vs analytic {}",
                    dqkv[idx]
                );
            }
        }
    }
}

#[test]
fn streaming_workspaces_hold_nothing_quadratic_and_never_reallocate() {
    // The workspace memory contract: at a long sequence the streaming
    // forward/backward workspaces stay strictly below any (t, t) panel and
    // far below the blocked footprint, and repeated calls never reallocate.
    let (batch, heads, hd, t_len, tile) = (1usize, 2usize, 8usize, 384usize, 32usize);
    let d = heads * hd;

    let ws = AttnWorkspace::new_streaming(t_len, hd, 2, tile);
    assert!(
        ws.max_slot_panel_floats() < t_len * t_len,
        "streaming forward workspace holds a (t, t)-sized panel"
    );
    assert!(ws.total_floats() < AttnWorkspace::new(t_len, hd, 2).total_floats());

    let gws = AttnGradWorkspace::new_streaming(t_len, hd, 2, tile);
    assert!(
        gws.total_floats() < 2 * (t_len * t_len),
        "streaming grad workspace is not linear in seq (total {} vs t² {})",
        gws.total_floats(),
        t_len * t_len
    );
    assert!(gws.total_floats() < AttnGradWorkspace::new(t_len, hd, 2).total_floats());

    // Zero per-call allocation on the streaming path, forward and backward.
    let mut rng = Rng::new(714);
    let qkv: Vec<f32> = (0..batch * t_len * 3 * d).map(|_| rng.normal() as f32).collect();
    let datt: Vec<f32> = (0..batch * t_len * d).map(|_| rng.normal() as f32).collect();
    let mut att = vec![0f32; batch * t_len * d];
    let mut dqkv = vec![0f32; batch * t_len * 3 * d];
    let mut ws = AttnWorkspace::new_streaming(t_len, hd, 2, tile);
    let mut gws = AttnGradWorkspace::new_streaming(t_len, hd, 2, tile);
    causal_attention(&qkv, batch, t_len, d, heads, &mut ws, &mut att, None);
    causal_attention_backward_streaming(&qkv, &datt, batch, t_len, d, heads, &mut gws, &mut dqkv);
    let (fp, gfp) = (ws.fingerprint(), gws.fingerprint());
    for _ in 0..3 {
        causal_attention(&qkv, batch, t_len, d, heads, &mut ws, &mut att, None);
        causal_attention_backward_streaming(
            &qkv, &datt, batch, t_len, d, heads, &mut gws, &mut dqkv,
        );
    }
    assert_eq!(ws.fingerprint(), fp, "streaming forward workspace reallocated");
    assert_eq!(gws.fingerprint(), gfp, "streaming grad workspace reallocated");
}
