//! Integration: the tiny-config **native** pipeline end to end — pretrain →
//! calibrate → DataSVD → sensitivity probe → DP rank selection → nested KD
//! consolidation → `profiles.json` → `load_native(Some(profiles))` →
//! `serve_trace` — fully offline, no feature flags, no artifacts.
//!
//! This pins the paper's train-once/deploy-everywhere loop: the DP
//! selection output actually drives deployment (at least one tier profile
//! differs from the uniform fallback) and bigger serving tiers are never
//! worse (per-tier eval loss monotone non-increasing in budget).
//!
//! Single #[test]: the run isolates its stage checkpoints via
//! `FLEXRANK_RESULTS`, which is process-global state.

use flexrank::config::RunConfig;
use flexrank::coordinator::{
    load_tier_profiles, serve_trace, PolicyKind, ServeCfg, SubmodelRegistry,
};
use flexrank::data::{Corpus, TokenBatcher, TraceCfg, TraceGen};
use flexrank::flexrank::masks::is_nested;
use flexrank::linalg::quant::Precision;
use flexrank::runtime::native::uniform_budget_profile;
use flexrank::runtime::ServingBackend;
use flexrank::training::{native, pipeline, CORPUS_BYTES};

#[test]
fn native_pipeline_to_dp_profile_serving_round_trip() {
    let dir = std::env::temp_dir().join(format!("flexrank_native_e2e_{}", std::process::id()));
    std::env::set_var("FLEXRANK_RESULTS", &dir);
    let _ = std::fs::create_dir_all(&dir);

    let cfg = flexrank::config::load_model_config("tiny").expect("configs/model_tiny.json");
    let mut rc = RunConfig::smoke();
    rc.pretrain_steps = 10;
    rc.consolidate_steps = 24;
    rc.calib_batches = 2;
    rc.eval_batches = 2;
    rc.probe_levels = 3;
    rc.budgets = vec![0.5, 1.0];
    rc.alphas = vec![0.5, 0.5];
    rc.seed = 1234;
    rc.log_every = 0;

    // --- pipeline ----------------------------------------------------------
    let out = pipeline::run_native(&cfg, &rc, true).expect("native pipeline failed");

    assert!(out.chain.validate(), "DP chain must be nested + cost-ascending");
    assert!(!out.chain.profiles.is_empty());
    assert!(out.full_cost > 0);
    assert_eq!(out.pretrain_losses.len(), rc.pretrain_steps);
    assert_eq!(out.kd_losses.len(), rc.consolidate_steps);
    assert!(out.pretrain_losses.iter().all(|l| l.is_finite()));
    assert!(out.kd_losses.iter().all(|l| l.is_finite()));
    assert_eq!(out.budget_rows.len(), 2);
    for (_, prof, before, after) in &out.budget_rows {
        assert_eq!(prof.len(), cfg.n_fact_layers());
        assert!(before.is_finite() && after.is_finite());
    }

    // --- profiles.json round trip ------------------------------------------
    assert!(pipeline::profiles_path().exists(), "pipeline must persist profiles.json");
    let tp = load_tier_profiles(&cfg, &out.student)
        .expect("profiles.json must parse")
        .expect("profiles.json must be picked up for the matching config");
    let profiles = tp.profiles.clone();
    assert_eq!(profiles, out.tier_profiles);
    assert_eq!(profiles.len(), cfg.serve_tiers.len());
    // The DP chain's measured per-tier calibration error rides along as the
    // router's difficulty signal.
    assert_eq!(tp.errors.len(), profiles.len());
    assert!(
        tp.errors.iter().all(|e| e.is_finite() && *e >= 0.0),
        "tier errors must be finite and non-negative: {:?}",
        tp.errors
    );
    for w in profiles.windows(2) {
        assert!(is_nested(&w[0], &w[1]), "tier profiles must be nested: {profiles:?}");
    }

    // The DP output must actually differ from what uniform fallback would
    // serve — otherwise selection never drove deployment.
    let uniform: Vec<Vec<usize>> =
        cfg.serve_tiers.iter().map(|&b| uniform_budget_profile(&cfg, b)).collect();
    assert!(
        profiles.iter().zip(&uniform).any(|(p, u)| p != u),
        "at least one DP profile must differ from the uniform fallback: {profiles:?}"
    );

    // --- per-tier quality is monotone in budget ----------------------------
    let corpus = Corpus::generate(CORPUS_BYTES, rc.seed);
    let eval_b = TokenBatcher::new(
        &corpus.heldout,
        cfg.batch_eval,
        cfg.seq_len + 1,
        cfg.vocab,
        rc.seed ^ 0x5A,
    );
    let eval_batches = eval_b.eval_batches(rc.eval_batches);
    let tier_losses: Vec<f64> = profiles
        .iter()
        .map(|p| native::eval_student(&cfg, &out.student, p, &eval_batches).unwrap())
        .collect();
    assert!(tier_losses.iter().all(|l| l.is_finite()));
    for w in tier_losses.windows(2) {
        assert!(
            w[1] <= w[0] + 1e-9,
            "eval loss must be monotone non-increasing as tier budget ascends: {tier_losses:?}"
        );
    }

    // --- serve the DP-selected submodels offline ---------------------------
    let mut registry = SubmodelRegistry::load_native(&cfg, &out.student, Some(&tp))
        .expect("registry must load DP profiles");
    assert_eq!(registry.n_tiers(), cfg.serve_tiers.len());
    for (tier, p) in registry.tiers.iter().zip(&profiles) {
        assert_eq!(&tier.profile, p, "registry must serve the DP profile verbatim");
    }
    for (t, e) in tp.errors.iter().enumerate() {
        assert_eq!(registry.tier_error(t), *e, "backend must expose the DP error verbatim");
    }
    let trace = TraceGen::new(
        TraceCfg {
            n_requests: 24,
            rate: 500.0,
            seq_len: cfg.seq_len,
            vocab: cfg.vocab,
            seed: 5,
            ..Default::default()
        },
        &corpus.heldout,
    )
    .expect("trace cfg must validate")
    .generate();
    let report = serve_trace(
        &mut registry,
        trace,
        &ServeCfg {
            policy: PolicyKind::Static,
            max_wait_ms: 1.0,
            replay_speed: 0.0,
            ..Default::default()
        },
    )
    .expect("serving over DP profiles failed");
    assert_eq!(report.metrics.requests_done, 24);
    assert_eq!(report.tier_requests.iter().sum::<usize>(), 24);
    for w in report.tier_params.windows(2) {
        assert!(w[0] < w[1], "tier params must ascend: {:?}", report.tier_params);
    }

    // --- quantized tier factors: serve within tolerance of f32 -------------
    // Tiny serves with batch_eval == batch_serve, so eval batches feed
    // `infer` directly: x is each row's first seq_len tokens, y the shift.
    let serving_ce = |reg: &mut SubmodelRegistry, tier: usize| -> f64 {
        let (b, s, v) = (cfg.batch_serve, cfg.seq_len, cfg.vocab);
        let (mut tot, mut n) = (0.0f64, 0usize);
        for batch in &eval_batches {
            let mut x = vec![0i32; b * s];
            let mut y = vec![0i32; b * s];
            for row in 0..b {
                let w = &batch[row * (s + 1)..(row + 1) * (s + 1)];
                x[row * s..(row + 1) * s].copy_from_slice(&w[..s]);
                y[row * s..(row + 1) * s].copy_from_slice(&w[1..]);
            }
            let logits = reg.infer(tier, &x).expect("serving infer for eval CE");
            for (t, &tgt) in y.iter().enumerate() {
                let row = &logits[t * v..(t + 1) * v];
                let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let lse =
                    row.iter().map(|&z| f64::from(z - mx).exp()).sum::<f64>().ln() + f64::from(mx);
                tot += lse - f64::from(row[tgt as usize]);
                n += 1;
            }
        }
        tot / n as f64
    };
    let f32_ce: Vec<f64> =
        (0..registry.n_tiers()).map(|t| serving_ce(&mut registry, t)).collect();
    assert!(f32_ce.iter().all(|l| l.is_finite()));
    for t in 0..registry.n_tiers() {
        assert_eq!(registry.tier_precision_label(t), "f32", "default tiers must store f32");
    }
    let mut cfg_q = cfg.clone();
    cfg_q.tier_precision = vec![Precision::I8, Precision::Bf16];
    let mut reg_q = SubmodelRegistry::load_native(&cfg_q, &out.student, Some(&tp))
        .expect("quantized registry must load");
    assert_eq!(reg_q.tier_precision_label(0), "i8");
    assert_eq!(reg_q.tier_precision_label(1), "bf16");
    for (tier, p) in reg_q.tiers.iter().zip(&profiles) {
        assert_eq!(&tier.profile, p, "quantization must not disturb the served profile");
    }
    let q_ce: Vec<f64> = (0..reg_q.n_tiers()).map(|t| serving_ce(&mut reg_q, t)).collect();
    assert!(q_ce.iter().all(|l| l.is_finite()));
    // i8 factors (tier 0) may drift more than bf16 (tier 1); both must stay
    // close to the f32 eval loss they approximate.
    assert!(
        (q_ce[0] - f32_ce[0]).abs() <= 0.25,
        "i8 tier eval CE {} too far from f32 {}",
        q_ce[0],
        f32_ce[0]
    );
    assert!(
        (q_ce[1] - f32_ce[1]).abs() <= 0.05,
        "bf16 tier eval CE {} too far from f32 {}",
        q_ce[1],
        f32_ce[1]
    );
    // Monotone in budget up to quantization slack: the bigger (bf16) tier
    // must not serve meaningfully worse than the smaller (i8) one.
    assert!(
        q_ce[1] <= q_ce[0] + 0.05,
        "quantized tiers must stay monotone in budget: {q_ce:?}"
    );

    // --- resume: a second run reuses every stage checkpoint ----------------
    let out2 = pipeline::run_native(&cfg, &rc, false).expect("checkpoint resume failed");
    assert!(out2.pretrain_losses.is_empty(), "teacher checkpoint must be reused");
    assert!(out2.kd_losses.is_empty(), "consolidated checkpoint must be reused");
    assert_eq!(out2.tier_profiles, profiles, "resumed DP selection must reproduce the profiles");

    // --- stale / malformed profiles.json handling --------------------------
    // A profiles.json written for a different config is stale, not fatal:
    // serving falls back to uniform profiles.
    let base_cfg = flexrank::config::load_model_config("base").expect("configs/model_base.json");
    // (The config-name check fires before the student is consulted, so the
    // tiny student stands in here.)
    assert!(
        load_tier_profiles(&base_cfg, &out.student)
            .expect("stale profiles must not error")
            .is_none(),
        "profiles written for 'tiny' must not be served for 'base'"
    );
    let ppath = pipeline::profiles_path();
    let good = std::fs::read_to_string(&ppath).unwrap();
    let good_fp = format!("{:016x}", out.student.content_fingerprint());
    assert!(
        good.contains(&format!("\"params_fp\":\"{good_fp}\"")),
        "profiles.json must record the consolidated student's content fingerprint: {good}"
    );
    // A profiles.json whose recorded full_cost disagrees with the loaded
    // student's GAR param count was written by an older run of this
    // same-named config (different checkpoint/student) — stale, so serving
    // must fall back to uniform instead of silently using wrong profiles.
    let tiers_json = |plen_ok: bool| {
        cfg.serve_tiers
            .iter()
            .zip(&profiles)
            .map(|(b, p)| {
                let ranks = if plen_ok {
                    p.iter().map(|r| r.to_string()).collect::<Vec<_>>().join(",")
                } else {
                    "3,3".to_string()
                };
                format!("{{\"budget\":{b},\"cost\":1,\"error\":0,\"profile\":[{ranks}]}}")
            })
            .collect::<Vec<_>>()
            .join(",")
    };
    let doc_json = |full_cost: u64, fp: &str, plen_ok: bool| {
        format!(
            "{{\"config\":\"{}\",\"full_cost\":{full_cost},\"params_fp\":\"{fp}\",\"tiers\":[{}]}}",
            cfg.name,
            tiers_json(plen_ok)
        )
    };
    std::fs::write(&ppath, doc_json(out.full_cost + 1, &good_fp, true)).unwrap();
    assert!(
        load_tier_profiles(&cfg, &out.student)
            .expect("mismatched full_cost is stale, not an error")
            .is_none(),
        "profiles DP'd for a different student must not be served"
    );
    // A file that claims to match this config *and* student but is
    // malformed (wrong profile length) is a hard error — never serve
    // silently wrong ranks.
    std::fs::write(&ppath, doc_json(out.full_cost, &good_fp, false)).unwrap();
    assert!(
        load_tier_profiles(&cfg, &out.student).is_err(),
        "a malformed profiles.json claiming to match the config must fail loudly"
    );
    std::fs::write(&ppath, good.clone()).unwrap();

    // --- params content-fingerprint: retraining invalidates profiles -------
    // A re-trained student has identical shapes (full_cost can't see it) but
    // different values: the content fingerprint flips, and load must fall
    // back to uniform rather than serve profiles DP'd on the old student.
    let mut retrained = out.student.clone();
    {
        let w = retrained
            .map
            .get_mut("blocks.0.qkv_u")
            .expect("student has blocks.0.qkv_u")
            .as_f32_mut()
            .unwrap();
        w[0] += 1e-3;
    }
    assert_ne!(
        retrained.content_fingerprint(),
        out.student.content_fingerprint(),
        "retraining (any value change) must flip the content fingerprint"
    );
    assert!(
        load_tier_profiles(&cfg, &retrained)
            .expect("fingerprint mismatch is stale, not an error")
            .is_none(),
        "profiles DP'd on the old student must not be served to a re-trained one"
    );
    // A pre-fingerprint profiles.json (no params_fp field) is unverifiable
    // and must fall back too.
    let legacy = good.replace(&format!("\"params_fp\":\"{good_fp}\","), "");
    assert!(!legacy.contains("params_fp"), "fixture edit failed: {legacy}");
    std::fs::write(&ppath, legacy).unwrap();
    assert!(
        load_tier_profiles(&cfg, &out.student)
            .expect("missing params_fp is stale, not an error")
            .is_none(),
        "a pre-fingerprint profiles.json must not be trusted"
    );
    std::fs::write(&ppath, good).unwrap();
    // And the original file still loads for the original student.
    assert!(load_tier_profiles(&cfg, &out.student).unwrap().is_some());

    std::env::remove_var("FLEXRANK_RESULTS");
    let _ = std::fs::remove_dir_all(&dir);
}
