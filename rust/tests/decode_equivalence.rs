//! The decode equivalence suite: the incremental prefill/decode path over
//! the paged K/V cache must reproduce the one-shot full-window forward at
//! every position to f32 rounding (1e-5), over a randomized grid that
//! includes every adversarial corner — page sizes that do and do not divide
//! the sequence, one-token prompts, decode-from-empty-cache, and
//! single-slot pools.  On top of that, continuous batching must be **bit**
//! identical to sequential replay: a request's rows depend only on its own
//! stream, so whatever batch composition it lands in, its logits match
//! byte for byte.  Finally, the decode loop's buffer identity is pinned
//! (zero per-step heap allocation) and the long-context config is pinned to
//! the streaming attention path.
//!
//! This file is the pin that makes the serving stack's incremental seam
//! safe: the coordinator can route any mix of prompt lengths through
//! prefill/decode and serve exactly what the one-shot window would have.

use flexrank::config::load_model_config;
use flexrank::coordinator::SubmodelRegistry;
use flexrank::prop::forall;
use flexrank::rng::Rng;
use flexrank::runtime::native::{DecodeScratch, GarSubmodel, Scratch};
use flexrank::runtime::{ModelConfig, PagedKvCache, ServingBackend};
use flexrank::training::params::{
    decompose_teacher, random_teacher, student_from_factors, ParamSet,
};

fn tiny_student(seed: u64) -> (ModelConfig, ParamSet) {
    let cfg = load_model_config("tiny").unwrap();
    let teacher = random_teacher(&cfg, seed);
    let factors = decompose_teacher(&cfg, &teacher, None).unwrap();
    let student = student_from_factors(&cfg, &teacher, &factors).unwrap();
    (cfg, student)
}

fn full_rank_model(cfg: &ModelConfig, student: &ParamSet) -> GarSubmodel {
    GarSubmodel::from_student(cfg, student, &vec![cfg.rank_full(); cfg.n_fact_layers()]).unwrap()
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{what}: length {} vs {}", got.len(), want.len()));
    }
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let scale = 1.0f32.max(w.abs());
        if (g - w).abs() > tol * scale {
            return Err(format!("{what}[{i}]: {g} vs {w} (tol {tol})"));
        }
    }
    Ok(())
}

/// Prefill + token-by-token decode ≡ the one-shot full window, at every
/// position, to 1e-5.  The prefill/decode boundary, the page size (both
/// dividing and not dividing the stream), the prompt length (down to one
/// token, and down to *zero* prefilled tokens — pure decode from an empty
/// cache), and the pool slot count are all randomized.
#[test]
fn property_decode_matches_full_window_at_every_position() {
    let (cfg, student) = tiny_student(11);
    let model = full_rank_model(&cfg, &student);
    let (d, heads, vocab) = (cfg.d_model, cfg.n_heads, cfg.vocab);
    let mut scratch = Scratch::for_config(&cfg, cfg.seq_len);

    forall(
        2718,
        24,
        |rng: &mut Rng| {
            let t_len = 1 + rng.below(cfg.seq_len);
            let page = 1 + rng.below(t_len + 2);
            let split = rng.below(t_len + 1); // prefill length; 0 = decode-only
            let slots = 1 + rng.below(3);
            let tokens: Vec<i32> =
                (0..t_len).map(|_| rng.below(vocab) as i32).collect();
            (t_len, page, split, slots, tokens)
        },
        |(t_len, page, split, slots, tokens)| {
            let (t_len, page, split, slots) = (*t_len, *page, *split, *slots);
            // Reference: one-shot window at the same positions.
            model
                .forward_window(tokens, 1, t_len, &mut scratch)
                .map_err(|e| e.to_string())?;
            let want = scratch.logits(t_len, vocab).to_vec();

            let mut cache = PagedKvCache::new(
                page,
                cfg.n_blocks,
                heads,
                d / heads,
                slots,
                cfg.seq_len,
                0,
            );
            let mut ds = DecodeScratch::new(t_len, d, heads, vocab, page);
            let slot = cache.try_acquire(t_len).ok_or("no slot")?;
            if split > 0 {
                model
                    .prefill(&tokens[..split], slot, &mut cache, &mut ds)
                    .map_err(|e| e.to_string())?;
                assert_close(
                    ds.logits(split, vocab),
                    &want[..split * vocab],
                    1e-5,
                    &format!("prefill rows (t_len {t_len} page {page} split {split})"),
                )?;
            }
            for pos in split..t_len {
                model
                    .decode_step(&tokens[pos..pos + 1], &[slot], &mut cache, &mut ds)
                    .map_err(|e| e.to_string())?;
                assert_close(
                    ds.logits(1, vocab),
                    &want[pos * vocab..(pos + 1) * vocab],
                    1e-5,
                    &format!("decode row {pos} (t_len {t_len} page {page} split {split})"),
                )?;
            }
            cache.release(slot);
            Ok(())
        },
    );
}

/// Continuous batching is **bit-identical** to sequential replay: each
/// decode row reads only its own stream's pages and its own scratch row, so
/// joining a running batch (or having neighbors complete mid-flight) cannot
/// perturb a request's logits even in the last ulp.
#[test]
fn continuous_batch_decode_is_bit_identical_to_sequential_replay() {
    let (cfg, student) = tiny_student(29);
    let mut reg = SubmodelRegistry::load_native(&cfg, &student, None).unwrap();
    let tier = 0;
    let vocab = cfg.vocab;
    let mut rng = Rng::new(501);
    // Four requests with distinct prompts, lengths, and generation budgets
    // (request 3 arrives late, joining the running batch mid-decode).
    let prompts: Vec<Vec<i32>> = [3usize, 7, 5, 4]
        .iter()
        .map(|&n| (0..n).map(|_| rng.below(vocab) as i32).collect())
        .collect();
    let gens = [4usize, 2, 5, 3];

    // Greedy decode one request in isolation; returns every sampled token.
    let sequential = |reg: &mut SubmodelRegistry, i: usize| -> Vec<i32> {
        let slot = reg.acquire_slot(prompts[i].len() + gens[i]).unwrap();
        let mut out = Vec::new();
        let mut last = {
            let logits = reg.prefill(tier, slot, &prompts[i]).unwrap();
            argmax(&logits[(prompts[i].len() - 1) * vocab..prompts[i].len() * vocab])
        };
        out.push(last);
        for _ in 1..gens[i] {
            let logits = reg.decode_step(tier, &[slot], &[last]).unwrap();
            last = argmax(&logits[..vocab]);
            out.push(last);
        }
        reg.release_slot(slot);
        out
    };
    let want: Vec<Vec<i32>> = (0..4).map(|i| sequential(&mut reg, i)).collect();

    // Continuous: requests 0..3 prefill together; request 3 joins after two
    // steps; requests retire as their budgets run out, shrinking the batch.
    let mut slots: Vec<Option<usize>> = (0..3)
        .map(|i| Some(reg.acquire_slot(prompts[i].len() + gens[i]).unwrap()))
        .collect();
    slots.push(None);
    let mut last = vec![0i32; 4];
    let mut got: Vec<Vec<i32>> = vec![Vec::new(); 4];
    for i in 0..3 {
        let logits = reg.prefill(tier, slots[i].unwrap(), &prompts[i]).unwrap();
        last[i] = argmax(&logits[(prompts[i].len() - 1) * vocab..prompts[i].len() * vocab]);
        got[i].push(last[i]);
    }
    let mut remaining: Vec<usize> = gens.iter().map(|g| g - 1).collect();
    remaining[3] = gens[3]; // not yet admitted
    let mut step = 0usize;
    loop {
        if step == 2 {
            // Late arrival joins the running batch between steps.
            let slot = reg.acquire_slot(prompts[3].len() + gens[3]).unwrap();
            slots[3] = Some(slot);
            let logits = reg.prefill(tier, slot, &prompts[3]).unwrap();
            last[3] = argmax(&logits[(prompts[3].len() - 1) * vocab..prompts[3].len() * vocab]);
            got[3].push(last[3]);
            remaining[3] -= 1;
        }
        let live: Vec<usize> =
            (0..4).filter(|&i| slots[i].is_some() && remaining[i] > 0).collect();
        if live.is_empty() {
            if step < 2 {
                step += 1; // keep ticking until the late arrival lands
                continue;
            }
            break;
        }
        let step_slots: Vec<usize> = live.iter().map(|&i| slots[i].unwrap()).collect();
        let step_tokens: Vec<i32> = live.iter().map(|&i| last[i]).collect();
        let sampled: Vec<i32> = {
            let logits = reg.decode_step(tier, &step_slots, &step_tokens).unwrap();
            (0..live.len()).map(|r| argmax(&logits[r * vocab..(r + 1) * vocab])).collect()
        };
        for (r, &i) in live.iter().enumerate() {
            last[i] = sampled[r];
            got[i].push(last[i]);
            remaining[i] -= 1;
            if remaining[i] == 0 {
                reg.release_slot(slots[i].take().unwrap());
            }
        }
        step += 1;
    }

    // Bit-identical: greedy argmax over bit-identical logits picks the
    // exact same token at every position of every request.
    for i in 0..4 {
        assert_eq!(
            got[i], want[i],
            "request {i}: continuous-batch decode diverged from sequential replay"
        );
    }
}

/// The decode loop performs zero per-step heap allocation: every buffer the
/// incremental path touches (K/V page pool, free list, page tables, decode
/// scratch) keeps its base pointer across admission / prefill / decode /
/// retire churn.
#[test]
fn decode_loop_is_allocation_free() {
    let (cfg, student) = tiny_student(43);
    let mut reg = SubmodelRegistry::load_native(&cfg, &student, None).unwrap();
    let fp = reg.decode_fingerprint();
    let mut rng = Rng::new(777);
    for round in 0..10 {
        let n = 1 + rng.below(cfg.batch_serve);
        let mut slots = Vec::new();
        for _ in 0..n {
            let plen = 1 + rng.below(cfg.seq_len - 4);
            let prompt: Vec<i32> = (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect();
            let Some(slot) = reg.acquire_slot(plen + 4) else { break };
            reg.prefill(round % reg.n_tiers(), slot, &prompt).unwrap();
            slots.push(slot);
        }
        for _ in 0..4 {
            let toks: Vec<i32> = slots.iter().map(|_| 1).collect();
            reg.decode_step(round % reg.n_tiers(), &slots, &toks).unwrap();
        }
        for slot in slots {
            reg.release_slot(slot);
        }
        assert_eq!(reg.decode_fingerprint(), fp, "round {round}: decode state reallocated");
    }
}

/// The long-context serving config crosses the streaming crossover, so the
/// production registry reports the streaming attention path — the `(Tc×hd)`
/// panel formulation the paged decode kernel tiles against.
#[test]
fn long_context_config_serves_the_streaming_attention_path() {
    let cfg = load_model_config("long").unwrap();
    let teacher = random_teacher(&cfg, 7);
    let factors = decompose_teacher(&cfg, &teacher, None).unwrap();
    let student = student_from_factors(&cfg, &teacher, &factors).unwrap();
    let reg = SubmodelRegistry::load_native(&cfg, &student, None).unwrap();
    let label = reg.attn_path_label();
    assert!(
        label.contains("streaming"),
        "long-context config must resolve the streaming path, got '{label}'"
    );
    assert!(reg.supports_decode() && reg.decode_slots() == cfg.batch_serve);
}

fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best as i32
}
