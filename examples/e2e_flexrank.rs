//! End-to-end driver over the full stack (DESIGN.md §validation): pretrains
//! the byte-GPT teacher on the native kernel backend, runs calibration +
//! DataSVD, DP rank selection, nested KD consolidation, and evaluates every
//! budget — logging the loss curves that EXPERIMENTS.md records.  Runs
//! fully offline (no artifacts, no PJRT); stages checkpoint under
//! `results/pipeline/` and the DP tier profiles land in
//! `results/pipeline/profiles.json` for `repro serve`.
//!
//! Run:
//!   cargo run --release --example e2e_flexrank            # full run (base)
//!   cargo run --release --example e2e_flexrank -- --smoke # few-step smoke
//!
//! Flags: --config base|tiny --pretrain-steps N --consolidate-steps N
//!        --seed S --fresh

use anyhow::Result;
use flexrank::cli::Args;
use flexrank::config::RunConfig;
use flexrank::training::pipeline;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let rc = if args.flag("smoke") {
        RunConfig::smoke().with_args(&args)?
    } else {
        RunConfig::default().with_args(&args)?
    };

    let cfg = flexrank::config::load_model_config(args.get_or("config", "base"))?;
    println!(
        "backend: native kernels — model {} (d={}, {} factorized layers)",
        cfg.name,
        cfg.d_model,
        cfg.n_fact_layers()
    );

    let out = pipeline::run_native(&cfg, &rc, args.flag("fresh"))?;

    println!("\n== pretraining loss curve (first/last 5) ==");
    let pl = &out.pretrain_losses;
    if !pl.is_empty() {
        let head: Vec<String> = pl.iter().take(5).map(|x| format!("{x:.3}")).collect();
        let tail: Vec<String> = pl.iter().rev().take(5).rev().map(|x| format!("{x:.3}")).collect();
        println!("  {} ... {}", head.join(" "), tail.join(" "));
    }
    println!("\n== consolidation KD-loss curve (first/last 5) ==");
    let kl = &out.kd_losses;
    if !kl.is_empty() {
        let head: Vec<String> = kl.iter().take(5).map(|x| format!("{x:.4}")).collect();
        let tail: Vec<String> = kl.iter().rev().take(5).rev().map(|x| format!("{x:.4}")).collect();
        println!("  {} ... {}", head.join(" "), tail.join(" "));
    }

    println!("\n== budget table (eval CE loss on held-out corpus) ==");
    println!("budget  datasvd-init  flexrank  profile-head");
    for (b, prof, before, after) in &out.budget_rows {
        println!(
            "  {b:.2}      {before:.4}     {after:.4}  {:?}",
            &prof[..4.min(prof.len())]
        );
    }
    println!("\nfull model inference cost: {} params (GAR form)", out.full_cost);
    println!(
        "serving tiers ({}): DP profiles in {}",
        out.tier_profiles.len(),
        pipeline::profiles_path().display()
    );
    println!("e2e_flexrank OK");
    Ok(())
}
