//! End-to-end driver over the full three-layer stack (DESIGN.md §validation):
//! pretrains the byte-GPT teacher through the PJRT `teacher_train_step`
//! artifact (L2+L1 compute lowered from jax/Pallas), runs DataSVD, DP
//! selection, nested KD consolidation, and evaluates every budget — logging
//! the loss curves that EXPERIMENTS.md records.
//!
//! Run (after `make artifacts && cargo build --release`):
//!   cargo run --release --example e2e_flexrank            # full run
//!   cargo run --release --example e2e_flexrank -- --smoke # 3-step smoke
//!
//! Flags: --pretrain-steps N --consolidate-steps N --seed S --fresh

use anyhow::Result;
use flexrank::cli::Args;
use flexrank::config::RunConfig;
use flexrank::runtime::Engine;
use flexrank::training::pipeline;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let rc = if args.flag("smoke") {
        RunConfig::smoke().with_args(&args)?
    } else {
        RunConfig::default().with_args(&args)?
    };

    let engine = Engine::new(flexrank::artifacts_dir())?;
    println!(
        "engine: platform={} model={} ({} factorized layers)",
        engine.platform(),
        engine.manifest.config.name,
        engine.manifest.config.n_fact_layers()
    );

    let out = pipeline::run(&engine, &rc, args.flag("fresh"))?;

    println!("\n== pretraining loss curve (first/last 5) ==");
    let pl = &out.pretrain_losses;
    if !pl.is_empty() {
        let head: Vec<String> = pl.iter().take(5).map(|x| format!("{x:.3}")).collect();
        let tail: Vec<String> = pl.iter().rev().take(5).rev().map(|x| format!("{x:.3}")).collect();
        println!("  {} ... {}", head.join(" "), tail.join(" "));
    }
    println!("\n== consolidation KD-loss curve (first/last 5) ==");
    let kl = &out.kd_losses;
    if !kl.is_empty() {
        let head: Vec<String> = kl.iter().take(5).map(|x| format!("{x:.4}")).collect();
        let tail: Vec<String> = kl.iter().rev().take(5).rev().map(|x| format!("{x:.4}")).collect();
        println!("  {} ... {}", head.join(" "), tail.join(" "));
    }

    println!("\n== budget table (eval CE loss on held-out corpus) ==");
    println!("budget  datasvd-init  flexrank  profile-head");
    for (b, prof, before, after) in &out.budget_rows {
        println!(
            "  {b:.2}      {before:.4}     {after:.4}  {:?}",
            &prof[..4.min(prof.len())]
        );
    }
    println!("\nfull model inference cost: {} params (GAR form)", out.full_cost);
    println!("e2e_flexrank OK");
    Ok(())
}
