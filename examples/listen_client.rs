//! Framed-protocol client for `repro serve --listen`.
//!
//! Connects to a running listener, pipelines a handful of requests over one
//! connection, and prints each id-tagged response as it lands (responses can
//! return out of submission order).  Uses the same byte codec
//! (`flexrank::data::trace::wire`) the listener tests and the serving bench
//! drive — this file doubles as the protocol's reference client.
//!
//! Run against a listener (in another terminal:
//! `cargo run --release -- serve --config tiny --listen`):
//!   cargo run --release --example listen_client
//!   cargo run --release --example listen_client -- --addr 127.0.0.1:7171 --requests 8 --gen 6

use std::io::Write;
use std::net::TcpStream;

use anyhow::{ensure, Context, Result};
use flexrank::cli::Args;
use flexrank::data::trace::wire::{self, Status};
use flexrank::data::trace::Slo;
use flexrank::data::Request;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let addr = args.get_or("addr", "127.0.0.1:7171");
    let n = args.usize_or("requests", 8)?;
    let gen_len = args.usize_or("gen", 6)?;

    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    stream.set_nodelay(true)?;

    // Pipeline every request up front; responses are id-tagged, so ordering
    // is recovered from the frames, not the socket.
    let mut out = Vec::new();
    for i in 0..n {
        let req = Request {
            id: i as u64 + 1,
            arrival_s: 0.0,
            slo: Slo::ALL[i % Slo::ALL.len()],
            // Small token ids are valid in every config's vocab.
            tokens: (0..8 + i % 8).map(|t| (t % 50) as i32).collect(),
            gen_len,
            budget: None,
        };
        wire::encode_request(&mut out, &req);
    }
    stream.write_all(&out)?;

    let mut buf = Vec::with_capacity(wire::MAX_PAYLOAD);
    for _ in 0..n {
        let magic = wire::read_frame(&mut stream, &mut buf, wire::MAX_PAYLOAD)?
            .context("server closed the connection early")?;
        ensure!(magic == wire::RESP_MAGIC, "unexpected frame magic 0x{magic:02x}");
        let (id, status, tokens) = wire::decode_response(&buf)?;
        match status {
            Status::Ok => println!("request {id}: ok, generated {tokens:?}"),
            Status::Shed => println!("request {id}: shed (queue saturated, retry later)"),
            Status::Error => println!("request {id}: rejected (malformed or out of contract)"),
        }
    }
    println!("listen_client OK");
    Ok(())
}
