//! Elastic serving demo: re-gauges one student into a GAR submodel per
//! budget tier, replays a Poisson request trace with mixed SLOs through the
//! coordinator (router → dynamic batcher → native kernel backend), and
//! reports per-tier latency + throughput — the paper's "deploy everywhere"
//! story under one roof.  Runs fully offline (no artifacts, no PJRT).
//!
//! Run:
//!   cargo run --release --example elastic_serving
//!   cargo run --release --example elastic_serving -- --policy adaptive --rate 400
//!   cargo run --release --example elastic_serving -- --policy elastic \
//!       --scenario bursty --queue-cap 32 --rate 2000

use anyhow::Result;
use flexrank::cli::Args;
use flexrank::coordinator::{
    load_tier_profiles, serve_trace, serving_student, PolicyKind, ServeCfg, SubmodelRegistry,
};
use flexrank::data::{ArrivalShape, Corpus, TenantCfg, TraceCfg, TraceGen};

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let cfg = flexrank::config::load_model_config(args.get_or("config", "base"))?;

    // Consolidated student checkpoint when available, else a freshly
    // decomposed random teacher (serving mechanics are identical).  Tier
    // profiles come from the pipeline's DP selection when profiles.json is
    // present, uniform budget ranks otherwise.
    let student = serving_student(&cfg, args.u64_or("seed", 7)?)?;
    let profiles = load_tier_profiles(&cfg, &student)?;
    let mut registry = SubmodelRegistry::load_native(&cfg, &student, profiles.as_ref())?;

    let corpus = Corpus::generate(200_000, 5);
    let trace = TraceGen::new(
        TraceCfg {
            n_requests: args.usize_or("requests", 300)?,
            rate: args.f64_or("rate", 250.0)?,
            seq_len: cfg.seq_len,
            vocab: cfg.vocab,
            seed: args.u64_or("seed", 7)?,
            // Arrival scenario + optional multi-tenant budget mix — the
            // load shapes the elastic controller is built to ride out.
            shape: ArrivalShape::parse(args.get_or("scenario", "steady"))?,
            tenants: if args.flag("tenants") { TenantCfg::default_mix() } else { Vec::new() },
            ..Default::default()
        },
        &corpus.heldout,
    )?
    .generate();

    let report = serve_trace(
        &mut registry,
        trace,
        &ServeCfg {
            policy: PolicyKind::parse(args.get_or("policy", "static"))?,
            max_wait_ms: args.f64_or("max-wait-ms", 4.0)?,
            // 0 = unbounded queue (serve everything); a positive cap turns
            // on explicit shed and anchors the demote-before-shed band.
            queue_cap: args.usize_or("queue-cap", 0)?,
            dwell_ms: args.f64_or("dwell-ms", 25.0)?,
            deadline_ms: args.f64_or("deadline-ms", 0.0)?,
            ..Default::default()
        },
    )?;
    report.print();
    println!("elastic_serving OK");
    Ok(())
}
