//! Elastic serving demo: loads the GAR tier executables, replays a Poisson
//! request trace with mixed SLOs through the coordinator (router → dynamic
//! batcher → PJRT), and reports per-tier latency + throughput — the paper's
//! "deploy everywhere" story under one roof.
//!
//! Run (after `make artifacts && cargo build --release`):
//!   cargo run --release --example elastic_serving
//!   cargo run --release --example elastic_serving -- --policy adaptive --rate 400

use anyhow::Result;
use flexrank::cli::Args;
use flexrank::coordinator::{serve_trace, PolicyKind, ServeCfg};
use flexrank::data::{Corpus, TraceCfg, TraceGen};
use flexrank::runtime::Engine;
use flexrank::training::params::{decompose_teacher, student_from_factors, ParamSet};

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let engine = Engine::new(flexrank::artifacts_dir())?;
    let cfg = engine.manifest.config.clone();

    // Use the consolidated student when available, else a freshly decomposed
    // teacher (serving mechanics are identical).
    let stem = flexrank::training::pipeline::stage_dir().join("student_kd");
    let student = if flexrank::training::ckpt::exists(&stem) {
        println!("using consolidated student checkpoint");
        flexrank::training::ckpt::load(&stem)?
    } else {
        println!("no pipeline checkpoint — decomposing fresh teacher");
        let teacher = ParamSet::from_specs(
            &engine.manifest.teacher_init,
            engine.manifest.load_teacher_init()?,
        );
        let factors = decompose_teacher(&cfg, &teacher, None)?;
        student_from_factors(&cfg, &teacher, &factors)?
    };

    let corpus = Corpus::generate(200_000, 5);
    let trace = TraceGen::new(
        TraceCfg {
            n_requests: args.usize_or("requests", 300)?,
            rate: args.f64_or("rate", 250.0)?,
            seq_len: cfg.seq_len,
            vocab: cfg.vocab,
            seed: args.u64_or("seed", 7)?,
            ..Default::default()
        },
        &corpus.heldout,
    )
    .generate();

    let policy = match args.get_or("policy", "static") {
        "adaptive" => PolicyKind::Adaptive,
        _ => PolicyKind::Static,
    };
    let report = serve_trace(
        &engine,
        &student,
        trace,
        &ServeCfg {
            policy,
            max_wait_ms: args.f64_or("max-wait-ms", 4.0)?,
            ..Default::default()
        },
    )?;
    report.print();
    println!("elastic_serving OK");
    Ok(())
}
