//! Controlled Pareto-recovery experiments (the paper's Sec. 3.4 / Sec. 4
//! story) as a single runnable: regenerates Figs. 2, 3, and 8 back to back
//! on pure-rust substrates — no artifacts required.
//!
//! Run: `cargo run --release --example pareto_recovery [-- --steps N]`

use anyhow::Result;
use flexrank::cli::Args;
use flexrank::eval::figures;

fn main() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    argv.insert(0, "figure".into());
    argv.insert(1, "fig2".into());
    let args = Args::parse(argv.clone());

    println!("=== Fig 2: PTS vs ASL vs NSL (linear theory) ===");
    figures::run_cli(&args)?;

    println!("\n=== Fig 3: Pareto-front recovery (digits net) ===");
    argv[1] = "fig3".into();
    figures::run_cli(&Args::parse(argv.clone()))?;

    println!("\n=== Fig 8: single-budget vs nested training ===");
    argv[1] = "fig8".into();
    figures::run_cli(&Args::parse(argv.clone()))?;

    println!("\npareto_recovery OK (CSVs under results/)");
    Ok(())
}
