//! Quickstart: FlexRank on a small pure-rust network — no artifacts needed.
//!
//! Demonstrates the full algorithmic loop in miniature (Alg. 1):
//!   1. train a dense teacher on synthetic digits,
//!   2. DataSVD-decompose it into importance-ordered factors,
//!   3. probe per-layer sensitivity + DP-select a nested chain,
//!   4. consolidate with nested sampling,
//!   5. extract GAR submodels across budgets and report the trade-off.
//!
//! Run: `cargo run --release --example quickstart`

use flexrank::baselines::controlled;
use flexrank::data::Digits;
use flexrank::flexrank::consolidate::{consolidate, ConsolidateCfg, Target};
use flexrank::flexrank::dp::{dp_rank_selection, Candidate};
use flexrank::flexrank::gar::Gar;
use flexrank::flexrank::masks::RankProfile;
use flexrank::nn::LayerKind;
use flexrank::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. Pretrained base model.
    let d = Digits::generate(800, 300, 42);
    let (teacher, acc) = controlled::train_dense_teacher(&d, 600, 43);
    println!("teacher: test accuracy {acc:.3}");

    // 2. DataSVD decomposition (activation-whitened, App. C.1).
    let student0 = controlled::decompose_net(&teacher, &d.x, false);
    let fulls = student0.fact_ranks();
    println!("factorized layers, full ranks: {fulls:?}");

    // 3. Sensitivity probe + DP rank selection (Alg. 2).
    let reference = student0.forward(&d.x_test, &fulls);
    let full_loss = controlled::eval_probe_mse(&student0, &d.x_test, &reference, &fulls);
    let dims: Vec<(usize, usize)> =
        student0.layers.iter().map(|l| (l.in_dim(), l.out_dim())).collect();
    let mut candidates = Vec::new();
    for (l, &full_r) in fulls.iter().enumerate() {
        let (n, m) = dims[l];
        let lp = |r: usize| ((n + m - r) * r) as u64;
        let mut cands = vec![Candidate { saving: 0, err: 0.0, rank: full_r }];
        for lvl in 1..8 {
            let r = ((full_r * lvl) as f64 / 8.0).ceil().max(1.0) as usize;
            let mut prof = fulls.clone();
            prof[l] = r;
            let e = controlled::eval_probe_mse(&student0, &d.x_test, &reference, &prof);
            cands.push(Candidate { saving: lp(full_r) - lp(r), err: (e - full_loss).max(0.0), rank: r });
        }
        cands.sort_by_key(|c| c.saving);
        candidates.push(cands);
    }
    let full_cost: u64 = fulls
        .iter()
        .zip(&dims)
        .map(|(&r, &(n, m))| ((n + m - r) * r) as u64)
        .sum();
    let dp = dp_rank_selection(&candidates, full_cost, 1)?;
    println!("DP: {} Pareto states, nested chain of {}", dp.pareto.len(), dp.chain.profiles.len());

    // 4. Nested consolidation on budget-selected profiles (Alg. 1, 14-17).
    let budgets = [0.3, 0.5, 0.7, 1.0];
    let profiles: Vec<RankProfile> = dp.chain.select(&budgets, full_cost as usize);
    let mut shared = student0.clone();
    let alphas = vec![0.25; 4];
    let mut rng = Rng::new(7);
    consolidate(
        &mut shared,
        &profiles,
        &alphas,
        &d.x,
        Target::Labels(&d.y),
        &ConsolidateCfg { steps: 2000, lr: 4e-3, batch: 64, log_every: 0 },
        &mut rng,
    );

    // 5. Deploy everywhere: GAR-extract each submodel and report.
    println!("\nbudget  params  test-acc  (GAR rank profile)");
    for (beta, prof) in budgets.iter().zip(&profiles) {
        let (_, acc) = controlled::eval_net(&shared, &d, prof);
        let params: usize = prof
            .iter()
            .zip(&dims)
            .map(|(&r, &(n, m))| Gar::macs(n, m, r))
            .sum();
        println!("  {beta:.1}   {params:>6}    {acc:.3}   {prof:?}");
        // Demonstrate an actual GAR extraction for the first layer.
        if let LayerKind::Fact(f) = &shared.layers[0].kind {
            let gar = Gar::from_factors(&f.u, &f.v, prof[0].max(1))?;
            assert_eq!(gar.rank, prof[0].max(1));
        }
    }
    println!("\nquickstart OK");
    Ok(())
}
